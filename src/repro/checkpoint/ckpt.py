"""Generic pytree checkpointing: flat-npz payload + a JSON meta manifest.

The format is two files per checkpoint, committed atomically:

  <base>.npz        every pytree leaf as a numpy array, keyed by its
                    "/"-joined tree path (dict keys and sequence indices);
                    dtypes numpy cannot serialize natively (bfloat16, fp8)
                    are stored as raw uint8 bytes and recorded in the meta
  <base>.meta.json  the manifest: format version, leaf keys, true
                    shapes/dtypes, which leaves are byte-packed, and an
                    `extra` dict for caller metadata (round offsets, lane
                    names, ...)

Writes go through `.tmp` paths and `os.replace`; the meta manifest is
renamed LAST, so its presence commits the checkpoint, and rewriting an
already-committed base unlinks the old manifest before the payload swap
— a crash mid-write leaves at most an orphaned (manifest-less) payload
that `latest_step` ignores, never an old manifest over a new payload.  A
failed write unlinks its own temp files (no `.tmp` litter on a full
disk).

Step-indexed layout (what the sweep engine's preemption-safe resume uses):

  save_pytree(dir, step, tree, extra=...)   -> <dir>/ckpt_<step>.{npz,meta.json}
  restore_pytree(dir, step=None, template=...)  # step=None -> latest
  latest_step(dir)                          # highest COMMITTED step, or None

`restore_pytree(template=...)` rebuilds exactly the template's container
structure (tuples stay tuples); with `template=None` the tree is rebuilt
from the recorded paths — dicts keyed by path component, with contiguous
integer components folded back into lists (tuples come back as lists, and
dict keys must not contain "/").  Restored arrays are byte-exact: the
round-trip is bitwise for every dtype, bfloat16 and complex included.

The pre-redesign params/opt_state-specific `save`/`restore` signatures are
kept as thin shims on top (and still read pre-redesign checkpoints, whose
meta carries no format_version and whose bf16 leaves were widened to f32).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

FORMAT_VERSION = 1

_META = ".meta.json"
_PAYLOAD = ".npz"


def _path_key(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path)] = leaf
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, _ in paths:
        leaves.append(flat[_path_key(path)])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _rebuild_from_paths(flat: Dict[str, Any]):
    """Rebuild a nested container tree from "/"-joined path keys alone:
    dicts keyed by path component, with any dict whose keys are exactly
    0..n-1 folded into a list (sequence indices round-trip as lists)."""
    if set(flat) == {""}:  # a bare leaf (the tree was a single array)
        return flat[""]
    root: Dict[str, Any] = {}
    for key, leaf in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf

    def fold(node):
        if not isinstance(node, dict):
            return node
        node = {k: fold(v) for k, v in node.items()}
        if node and all(k.isdigit() for k in node):
            idx = sorted(int(k) for k in node)
            if idx == list(range(len(node))):
                return [node[str(i)] for i in idx]
        return node

    return fold(root)


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # ships with jax
        return np.dtype(getattr(ml_dtypes, name))


def _pack(a: np.ndarray) -> Tuple[np.ndarray, bool]:
    """npz-safe representation: native numpy dtypes pass through; extension
    dtypes (bfloat16, fp8, ...) become their raw bytes (exactness is the
    whole point — the old format widened bf16 to f32 and lost the bits)."""
    try:
        np.lib.format.dtype_to_descr(a.dtype)
        if a.dtype.kind != "V":
            return a, False
    except ValueError:
        pass
    return np.frombuffer(np.ascontiguousarray(a).tobytes(), np.uint8), True


def _cleanup(*paths: str) -> None:
    for p in paths:
        try:
            os.remove(p)
        except OSError:
            pass


def write_tree(base: str, tree, extra: Optional[dict] = None) -> str:
    """Write one checkpoint at <base>.npz + <base>.meta.json (atomic: temp
    files renamed into place, the meta manifest last — its presence is the
    commit).  Returns the payload path."""
    flat = {k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()}
    arrays, packed = {}, []
    for k, v in flat.items():
        a, was_packed = _pack(v)
        arrays[k] = a
        if was_packed:
            packed.append(k)
    meta = {
        "format_version": FORMAT_VERSION,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "packed": sorted(packed),
        "extra": extra or {},
    }
    tmp_npz = base + ".tmp" + _PAYLOAD
    tmp_meta = base + _META + ".tmp"
    try:
        np.savez(tmp_npz, **arrays)
        with open(tmp_meta, "w") as f:
            json.dump(meta, f)
        # Rewriting an already-committed base must never pair the OLD
        # manifest with the NEW payload: with both temp files staged,
        # decommit (unlink the old manifest) BEFORE replacing the payload,
        # then rename the new manifest — the commit.  A crash anywhere in
        # between leaves at most a manifest-less payload that latest_step
        # ignores, never a mixed pair.
        try:
            os.remove(base + _META)
        except FileNotFoundError:
            pass
        os.replace(tmp_npz, base + _PAYLOAD)
        os.replace(tmp_meta, base + _META)  # commit point
    except BaseException:
        _cleanup(tmp_npz, tmp_meta)
        raise
    return base + _PAYLOAD


def read_tree(base: str, template=None) -> Tuple[Any, dict]:
    """Read a checkpoint written by `write_tree` (or the pre-redesign
    `save`).  Returns (tree, meta): arrays byte-exact as stored, container
    structure from `template` when given (tuples and custom nodes preserved)
    or rebuilt from the recorded paths otherwise."""
    with open(base + _META) as f:
        meta = json.load(f)
    with np.load(base + _PAYLOAD) as z:
        flat = {k: z[k] for k in z.files}
    for k in meta.get("packed", ()):
        dt = _dtype_from_name(meta["dtypes"][k])
        flat[k] = np.frombuffer(flat[k].tobytes(), dt).reshape(
            meta["shapes"][k])
    tree = (_rebuild_from_paths(flat) if template is None
            else _unflatten_like(template, flat))
    return tree, meta


def _base(path: str, step: int) -> str:
    return os.path.join(path, f"ckpt_{step}")


def save_pytree(path: str, step: int, tree,
                extra: Optional[dict] = None) -> str:
    """Write `tree` as step `step` under directory `path` (created if
    needed).  Atomic — see `write_tree`.  Returns the payload path."""
    os.makedirs(path, exist_ok=True)
    extra = dict(extra or {})
    extra.setdefault("step", int(step))
    return write_tree(_base(path, step), tree, extra=extra)


def restore_pytree(path: str, step: Optional[int] = None,
                   template=None) -> Tuple[Any, dict]:
    """Read step `step` (None -> `latest_step(path)`) from directory `path`.
    Raises FileNotFoundError when the directory holds no committed step."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint under {path!r}")
    return read_tree(_base(path, step), template=template)


def latest_step(path: str) -> Optional[int]:
    """Highest COMMITTED step in `path`: a step counts only when both its
    payload and its meta manifest exist (the manifest rename is the commit),
    so torn writes and foreign files are ignored, not crashed on."""
    if not os.path.isdir(path):
        return None
    steps = []
    for f in os.listdir(path):
        if not (f.startswith("ckpt_") and f.endswith(_PAYLOAD)):
            continue
        stem = f[len("ckpt_"):-len(_PAYLOAD)]
        if not stem.isdigit():
            continue
        if os.path.exists(os.path.join(path, f"ckpt_{stem}{_META}")):
            steps.append(int(stem))
    return max(steps) if steps else None


# --------------------------------------------------------------------------
# Pre-redesign params/opt_state API — thin shims over the generic pytree
# format.  `save` now stores every dtype exactly (the old format widened
# bf16 to f32); `restore` still casts to the template's dtypes, so it reads
# both new checkpoints (no-op cast) and pre-redesign ones (widened leaves
# cast back down, as before).


def save(path: str, step: int, params, opt_state=None,
         extra: Optional[dict] = None, specs=None) -> str:
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    extra = dict(extra or {})
    if specs is not None:
        extra["specs"] = {
            k: [str(a) for a in (tuple(v) if v else ())]
            for k, v in _flatten_with_paths({"params": specs}).items()
        }
    return save_pytree(path, step, tree, extra=extra)


def restore(path: str, step: int, params_template, opt_template=None
            ) -> Tuple[Any, Any, dict]:
    tmpl = {"params": params_template}
    if opt_template is not None:
        tmpl["opt_state"] = opt_template
    tree, meta = restore_pytree(path, step, template=tmpl)
    tree = jax.tree_util.tree_map(
        lambda t, v: jax.numpy.asarray(v).astype(t.dtype), tmpl, tree)
    params = tree["params"]
    opt_state = tree.get("opt_state") if opt_template is not None else None
    return params, opt_state, meta
