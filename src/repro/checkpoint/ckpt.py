"""Checkpointing: flat-npz format with pytree structure + sharding metadata.

save(path, step, params, opt_state, extra) writes
  <path>/ckpt_<step>.npz        flattened arrays keyed by pytree path
  <path>/ckpt_<step>.meta.json  treedef repr, shapes/dtypes, partition specs
restore() rebuilds the pytree; on a mesh the launcher device_puts each leaf
with its recorded NamedSharding.  Atomic via tmp-file rename.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(path: str, step: int, params, opt_state=None, extra: Optional[dict] = None,
         specs=None) -> str:
    os.makedirs(path, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat = _flatten_with_paths(tree)

    def to_np(v):
        a = np.asarray(v)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = np.asarray(jax.numpy.asarray(v, jax.numpy.float32))
        return a

    arrays = {k: to_np(v) for k, v in flat.items()}
    meta = {
        "step": int(step),
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    if specs is not None:
        meta["specs"] = {
            k: [str(a) for a in (tuple(v) if v else ())]
            for k, v in _flatten_with_paths({"params": specs}).items()
        }
    base = os.path.join(path, f"ckpt_{step}")
    tmp = base + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, base + ".npz")
    with open(base + ".meta.json.tmp", "w") as f:
        json.dump(meta, f)
    os.replace(base + ".meta.json.tmp", base + ".meta.json")
    return base + ".npz"


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(f[len("ckpt_") : -len(".npz")])
        for f in os.listdir(path)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore(path: str, step: int, params_template, opt_template=None
            ) -> Tuple[Any, Any, dict]:
    base = os.path.join(path, f"ckpt_{step}")
    with np.load(base + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    tmpl = {"params": params_template}
    if opt_template is not None:
        tmpl["opt_state"] = opt_template
    # dtype-faithful restore: cast back to the template's dtype (bf16 etc.
    # were stored widened to f32 — see save())
    tree = _unflatten_like(tmpl, flat)
    tree = jax.tree_util.tree_map(
        lambda t, v: jax.numpy.asarray(v).astype(t.dtype), tmpl, tree
    )
    params = tree["params"]
    opt_state = tree.get("opt_state") if opt_template is not None else None
    return params, opt_state, meta
