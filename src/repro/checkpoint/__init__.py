from repro.checkpoint.ckpt import (FORMAT_VERSION, latest_step, read_tree,
                                   restore, restore_pytree, save, save_pytree,
                                   write_tree)

__all__ = [
    "FORMAT_VERSION",
    "latest_step",
    "read_tree",
    "restore",
    "restore_pytree",
    "save",
    "save_pytree",
    "write_tree",
]
