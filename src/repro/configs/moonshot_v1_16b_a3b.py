"""Moonlight-16B-A3B (Moonshot) [hf:moonshotai/Moonlight-16B-A3B].

DeepSeek-V3-style MoE: 64 routed experts top-6 + 2 shared, per-expert FFN
width 1408, MHA-ish kv=16.  long_500k uses the explicit 8192 sliding-window
long-context variant (flagged; the published model is full-attention).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig, MoEConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",               # per assignment bracket ([dense] w/ MoE)
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        block_pattern=("attn_moe",),
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                      impl="scan_dense"),
        long_context_window=8192,
        rope_theta=5e4,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="hf:moonshotai/Moonlight-16B-A3B — 64e top-6 + 2 shared, kv=16",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=512, dtype=jnp.float32, remat=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, num_shared=1,
                      impl="scan_dense"),
    )
