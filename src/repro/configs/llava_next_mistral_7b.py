"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: the SigLIP/CLIP vision tower is stubbed per spec — input_specs supplies
precomputed anyres patch embeddings [B, 2880, 1024]; the 2-layer MLP projector
and the Mistral-7B backbone (GQA kv=8, native SWA 4096) are real.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import FrontendConfig, ModelConfig

ARCH_ID = "llava-next-mistral-7b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        window=4096,                      # Mistral native SWA
        rope_theta=1e6,
        frontend=FrontendConfig(kind="vision", feature_dim=1024, n_prefix=2880),
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="hf:llava-hf/llava-v1.6-mistral-7b-hf — anyres tiling, "
                 "Mistral-7B GQA kv=8 SWA 4096",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, window=64, dtype=jnp.float32, remat=False,
        frontend=FrontendConfig(kind="vision", feature_dim=64, n_prefix=16),
    )
