"""Architecture registry: --arch <id> -> config module.

Every assigned architecture (10, spanning 6 families) plus the paper's own
MLP.  Each module exposes full(model_parallel) and smoke().
"""
from __future__ import annotations

from typing import Dict

from repro.configs import (
    deepseek_v2_236b,
    granite_8b,
    llama4_maverick_400b_a17b,
    llava_next_mistral_7b,
    mamba2_1_3b,
    moonshot_v1_16b_a3b,
    paper_mlp,
    qwen3_4b,
    recurrentgemma_9b,
    seamless_m4t_large_v2,
    starcoder2_3b,
)

ARCH_MODULES: Dict[str, object] = {
    m.ARCH_ID: m
    for m in [
        starcoder2_3b,
        llava_next_mistral_7b,
        moonshot_v1_16b_a3b,
        mamba2_1_3b,
        deepseek_v2_236b,
        qwen3_4b,
        recurrentgemma_9b,
        granite_8b,
        llama4_maverick_400b_a17b,
        seamless_m4t_large_v2,
    ]
}

ARCH_IDS = list(ARCH_MODULES)
PAPER_MLP = paper_mlp

# The assigned input shapes (system spec).
INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(arch: str, model_parallel: int = 16):
    return ARCH_MODULES[arch].full(model_parallel=model_parallel)


def get_smoke(arch: str):
    return ARCH_MODULES[arch].smoke()


def get_lm_sweep(arch: str = "qwen3-4b"):
    """The config an arch contributes to the sweep engine's real-model LM
    lane (flat-state FLOA sweeps at D ~ 1e6+).  Only archs that define an
    `lm_sweep()` variant participate; KeyError/AttributeError otherwise."""
    return ARCH_MODULES[arch].lm_sweep()


def flat_param_dim(cfg) -> int:
    """Flat parameter count D of a config — the sweep engine's state-row
    width.  Allocation-free (shape_only init), so it is cheap even for the
    236B-class configs."""
    import jax

    from repro.launch.steps import init_model

    params, _ = init_model(cfg, jax.random.PRNGKey(0), shape_only=True)
    return sum(int(_size(x)) for x in jax.tree_util.tree_leaves(params))


def _size(x) -> int:
    import math
    return math.prod(x.shape)


def shape_applicable(cfg, shape_name: str) -> bool:
    return shape_name not in cfg.skip_shapes
