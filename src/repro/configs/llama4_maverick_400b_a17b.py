"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

Interleaved dense/MoE layers (pattern attn, attn_moe), 128 routed experts
top-1 + 1 shared, GQA kv=8.  40 heads do not divide the 16-way model axis ->
d-dim weight sharding fallback.  Early-fusion multimodality in the published
model is out of the assigned backbone scope (text tokens only here).
long_500k uses the 8192 SWA variant (the published model's iRoPE chunked
attention is likewise windowed).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig, MoEConfig

ARCH_ID = "llama4-maverick-400b-a17b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        block_pattern=("attn", "attn_moe"),
        moe=MoEConfig(num_experts=128, top_k=1, d_expert=8192, num_shared=1,
                      impl="scan_dense"),
        long_context_window=8192,
        rope_theta=5e5,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick cfg) — "
                 "MoE 128e top-1, interleaved dense/MoE",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, dtype=jnp.float32, remat=False,
        moe=MoEConfig(num_experts=4, top_k=1, d_expert=256, num_shared=1,
                      impl="scan_dense"),
    )
