"""RecurrentGemma-9B [arXiv:2402.19427] — Griffin hybrid: RG-LRU + local attn.

38 layers in a 2:1 (recurrent, recurrent, local-attention) pattern
(12 scanned repeats + 2 RG-LRU tail blocks), MQA kv=1, local window 2048.
long_500k runs natively (constant recurrent state + 2048-window cache).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCH_ID = "recurrentgemma-9b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048,
        rglru_width=4096,
        rope_theta=1e4,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="arXiv:2402.19427 (Griffin/RecurrentGemma) — RG-LRU + "
                 "local attn 1:2, MQA kv=1",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, local_window=32, rglru_width=128,
        dtype=jnp.float32, remat=False,
    )
