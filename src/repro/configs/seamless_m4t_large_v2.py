"""SeamlessM4T-large-v2 [arXiv:2308.11596] — multimodal encoder-decoder.

The assigned 24 layers are split 12 encoder + 12 decoder (documented
interpretation; the published model stacks several sub-networks).  The speech
codec frontend is stubbed per spec: the encoder consumes precomputed frame
embeddings [B, T, 1024].  long_500k is SKIPPED: full self+cross attention
enc-dec with no published sub-quadratic variant (DESIGN.md §5).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import EncDecConfig, FrontendConfig, ModelConfig

ARCH_ID = "seamless-m4t-large-v2"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        encdec=EncDecConfig(n_enc_layers=12, n_dec_layers=12, enc_seq_cap=4096),
        frontend=FrontendConfig(kind="audio", feature_dim=1024),
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        skip_shapes=("long_500k",),
        citation="arXiv:2308.11596 (SeamlessM4T v2) — enc-dec, multimodal",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, dtype=jnp.float32, remat=False,
        encdec=EncDecConfig(n_enc_layers=2, n_dec_layers=2, enc_seq_cap=32),
        frontend=FrontendConfig(kind="audio", feature_dim=64),
    )
