"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSD (state-space duality).

48 layers, d_model 2048 (d_inner 4096, 64 heads of headdim 64, d_state 128).
Decode (incl. long_500k) carries a constant [B, H, N, P] recurrent state — no
KV cache, the arch's whole point for long context.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig, SSMConfig

ARCH_ID = "mamba2-1.3b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=64,                       # d_inner/headdim (bookkeeping only)
        n_kv_heads=64,
        d_ff=0,                           # Mamba blocks have no separate FFN
        vocab_size=50280,
        block_pattern=("ssm",),
        ssm=SSMConfig(d_state=128, expand=2, headdim=64, chunk=256, d_conv=4,
                      ngroups=1),
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="arXiv:2405.21060 (Mamba-2 SSD), ssm_state=128",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, vocab_size=512,
        ssm=SSMConfig(d_state=16, expand=2, headdim=32, chunk=16, d_conv=4,
                      ngroups=1),
        dtype=jnp.float32, remat=False,
    )
