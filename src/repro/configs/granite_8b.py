"""Granite-8B-Code [arXiv:2405.04324] — llama-architecture dense code LM.

Full attention natively; long_500k uses the explicit 8192 SWA variant.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCH_ID = "granite-8b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=49152,
        long_context_window=8192,
        rope_theta=1e4,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="arXiv:2405.04324 (Granite Code) — llama arch, GQA kv=8",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, dtype=jnp.float32, remat=False,
    )
