"""The paper's own experiment config (§IV): MLP 784-64-10 on 28x28 digits,
U=10 workers, 3000 training samples, SNR 10 dB, Rayleigh CN(0,1) channels."""
import dataclasses

ARCH_ID = "paper-mlp"


@dataclasses.dataclass(frozen=True)
class PaperMLPConfig:
    d_in: int = 784
    d_hidden: int = 64
    n_classes: int = 10
    num_workers: int = 10
    train_samples: int = 3000
    test_samples: int = 1000
    batch_per_worker: int = 32
    snr_db: float = 10.0
    sigma: float = 1.0
    p_max: float = 1.0

    @property
    def dim(self) -> int:  # D = 50890, as in the paper
        return (self.d_in * self.d_hidden + self.d_hidden
                + self.d_hidden * self.n_classes + self.n_classes)


def full() -> PaperMLPConfig:
    return PaperMLPConfig()


def smoke() -> PaperMLPConfig:
    return dataclasses.replace(full(), train_samples=200, test_samples=100,
                               batch_per_worker=8)
