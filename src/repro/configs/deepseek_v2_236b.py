"""DeepSeek-V2 236B [arXiv:2405.04434] — MLA + 160-expert MoE.

MLA (kv_lora=512 + 64 rope dims/token cache => 1152 B/token bf16) makes full
attention over a 524288-token cache feasible sharded — long_500k runs without
a window variant, unlike the dense archs.  2 shared + 160 routed top-6
experts, per-expert width 1536.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-236b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=1536,
        vocab_size=102400,
        block_pattern=("attn_moe",),
        mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                      impl="scan_dense"),
        rope_theta=1e4,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="arXiv:2405.04434 (DeepSeek-V2) — MLA kv_lora=512, "
                 "2 shared + 160 routed top-6",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=512, dtype=jnp.float32, remat=False,
        mla=MLAConfig(q_lora=64, kv_lora=32, qk_nope_dim=32, qk_rope_dim=16,
                      v_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128, num_shared=1,
                      impl="scan_dense"),
    )
