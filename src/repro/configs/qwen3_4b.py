"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — dense GQA with qk-norm, head_dim 128.

Full attention natively; long_500k uses the explicit 8192 SWA variant.
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCH_ID = "qwen3-4b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        long_context_window=8192,
        rope_theta=1e6,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="hf:Qwen/Qwen3-8B — qk_norm, GQA kv=8",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, dtype=jnp.float32, remat=False,
    )


def lm_sweep() -> ModelConfig:
    """The sweep engine's real-model LM lane: a shrunk qwen3-shaped
    transformer whose flat parameter count D ≈ 3e6 — large enough to drive
    `floa_step_batched` / `grad_stats` / `defense_sort` past their 2^14 /
    2^16 kernel-routing thresholds at production D, small enough that the
    [S, U, D] gradient slab of a few-lane sweep fits host memory.  f32 and
    remat-free so flat-state sweeps stay bitwise-reproducible."""
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=1024, vocab_size=2048, dtype=jnp.float32, remat=False,
    )
