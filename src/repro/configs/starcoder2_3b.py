"""StarCoder2-3B [arXiv:2402.19173] — dense GQA code LM with native 4096
sliding-window attention and RoPE.  24 heads do not divide the 16-way model
axis, so attention weights shard on the d_model contraction dim (DESIGN.md §4).
"""
import dataclasses

import jax.numpy as jnp

from repro.models.common import ModelConfig

ARCH_ID = "starcoder2-3b"


def full(model_parallel: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab_size=49152,
        window=4096,                      # native SWA -> long_500k runs as-is
        rope_theta=1e5,
        dtype=jnp.bfloat16,
        model_parallel=model_parallel,
        citation="arXiv:2402.19173 (StarCoder2), GQA kv=2, SWA 4096, RoPE",
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        full(model_parallel=1),
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, window=64, dtype=jnp.float32, remat=False,
    )
