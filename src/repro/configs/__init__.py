from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    ARCH_MODULES,
    INPUT_SHAPES,
    PAPER_MLP,
    flat_param_dim,
    get_config,
    get_lm_sweep,
    get_smoke,
    shape_applicable,
)
