from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    ARCH_MODULES,
    INPUT_SHAPES,
    PAPER_MLP,
    get_config,
    get_smoke,
    shape_applicable,
)
