"""Deterministic sharded batch pipeline.

Host-side (numpy) iterator producing global batches; on a mesh the launcher
feeds them through jax.device_put with the batch PartitionSpec.  Per-worker
federated sampling matches the paper: each worker holds an i.i.d. local shard
and samples its own minibatch each round; the global batch is the
concatenation ordered by worker index (so batch.reshape(U, -1, ...) recovers
worker locality — the layout per_worker_grads expects).

Non-IID partitions (beyond the paper's i.i.d. assumption, for the adaptive-
adversary experiments): `dirichlet_worker_split` deals each class's samples
across workers with proportions drawn from Dirichlet(alpha * 1_U) — the
standard federated label-skew benchmark.  alpha -> 0 concentrates each class
on few workers; alpha = np.inf takes exact proportions 1/U (no draw at all),
degenerating to a deterministic stratified IID split — the pinned
alpha -> inf contract (tests/test_data_pipeline.py).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np


def dirichlet_worker_split(
    x: np.ndarray, y: np.ndarray, num_workers: int, alpha: float,
    seed: int = 0, min_per_worker: int = 1,
) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """Dirichlet(alpha) label-skew partition of (x, y) into U worker shards.

    Per class c: shuffle its sample indices, draw proportions
    p ~ Dirichlet(alpha * 1_U) (or p = 1/U exactly when alpha = np.inf —
    same code path, no RNG consumption difference beyond skipping the draw),
    and deal contiguous slices at the cumulative-proportion boundaries.  Any
    worker left under `min_per_worker` samples steals from the largest shard
    (deterministic, largest-first), so every worker can always draw a batch.
    """
    if not (alpha > 0.0):  # also rejects NaN
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    if num_workers < 1:
        raise ValueError(f"num_workers must be >= 1, got {num_workers}")
    if len(x) < num_workers * min_per_worker:
        raise ValueError(
            f"{len(x)} samples cannot give {num_workers} workers "
            f">= {min_per_worker} each")
    rng = np.random.default_rng(seed)
    per_worker = [[] for _ in range(num_workers)]
    for c in np.unique(y):
        idx = np.flatnonzero(y == c)
        rng.shuffle(idx)
        if np.isinf(alpha):
            p = np.full(num_workers, 1.0 / num_workers)
        else:
            p = rng.dirichlet(np.full(num_workers, float(alpha)))
        cuts = np.floor(np.cumsum(p)[:-1] * len(idx)).astype(np.int64)
        for i, part in enumerate(np.split(idx, cuts)):
            per_worker[i].append(part)
    shards = [np.concatenate(parts) if parts else np.empty(0, np.int64)
              for parts in per_worker]
    # Rebalance floor: move samples from the currently-largest shard to any
    # worker below min_per_worker (stable order -> deterministic shards).
    for i in range(num_workers):
        while len(shards[i]) < min_per_worker:
            j = int(np.argmax([len(s) for s in shards]))
            shards[i] = np.concatenate([shards[i], shards[j][-1:]])
            shards[j] = shards[j][:-1]
    return {i: (x[s], y[s]) for i, s in enumerate(shards)}


def iter_chunk_blocks(batches, chunk_rounds: int) -> Iterator:
    """Slice a stacked [R, ...] batch pytree into consecutive [C, ...] blocks.

    Yields ceil(R / chunk_rounds) blocks in round order; the last block
    carries R % chunk_rounds rounds when R is not divisible, so concatenating
    the blocks on axis 0 reproduces the input exactly.  On numpy inputs each
    block leaf is a zero-copy view — this is the host half of the chunked
    sweep engine's input pipeline: the engine stages block k+1 to the device
    (`launch.mesh.stage_batch_block`) while chunk k computes, so the full
    [R, ...] stack never has to live in device memory.
    """
    if chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
    for start in range(0, rounds, chunk_rounds):
        yield jax.tree_util.tree_map(
            lambda x: x[start:start + chunk_rounds], batches)


class FederatedSampler:
    """Round-based sampler over per-worker data shards."""

    def __init__(self, shards: Dict[int, tuple], batch_per_worker: int, seed: int = 0):
        self.shards = shards
        self.bpw = batch_per_worker
        self.rng = np.random.default_rng(seed)

    @classmethod
    def dirichlet(cls, x: np.ndarray, y: np.ndarray, num_workers: int,
                  alpha: float, batch_per_worker: int,
                  seed: int = 0) -> "FederatedSampler":
        """Sampler over a Dirichlet(alpha) label-skew partition
        (`dirichlet_worker_split`); alpha = np.inf is the stratified IID
        degenerate."""
        shards = dirichlet_worker_split(x, y, num_workers, alpha, seed=seed)
        return cls(shards, batch_per_worker, seed=seed)

    @property
    def num_workers(self) -> int:
        return len(self.shards)

    def next_round(self) -> Dict[str, np.ndarray]:
        xs, ys = [], []
        for i in range(self.num_workers):
            x, y = self.shards[i]
            idx = self.rng.integers(0, len(x), size=self.bpw)
            xs.append(x[idx])
            ys.append(y[idx])
        return {"x": np.concatenate(xs), "y": np.concatenate(ys)}

    def stack_rounds(self, rounds: int) -> Dict[str, np.ndarray]:
        """Pre-draw `rounds` batches stacked on a leading [R] axis — the input
        layout the compiled scan engines (FLTrainer.run_scan, fl.sweep)
        consume.  Draws from the same RNG stream as repeated next_round()
        calls, so a fresh same-seed sampler replays the identical sequence."""
        draws = [self.next_round() for _ in range(rounds)]
        return {k: np.stack([d[k] for d in draws]) for k in draws[0]}

    def iter_round_chunks(self, rounds: int,
                          chunk_rounds: int) -> Iterator[Dict[str, np.ndarray]]:
        """Yield `rounds` worth of batches as stacked [C, ...] blocks of
        `chunk_rounds` rounds each (last block shorter when R % C != 0).

        Draws from the same RNG stream as `stack_rounds(rounds)` — the
        concatenation of the yielded blocks is identical to one big stack —
        but only ever materializes one block at a time, so a long sweep's
        batch stream can be produced incrementally on the host while the
        chunked engine runs."""
        done = 0
        while done < rounds:
            yield self.stack_rounds(min(chunk_rounds, rounds - done))
            done += chunk_rounds


class TokenBatcher:
    """Iterates [global_batch, seq_len] token batches from a generator fn."""

    def __init__(self, sample_fn: Callable[[int, int], np.ndarray],
                 global_batch: int, seq_len: int, seed: int = 0):
        self.sample_fn = sample_fn
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.sample_fn(self.global_batch, self.seq_len + 1)
        self.step += 1
        return {"tokens": batch}
