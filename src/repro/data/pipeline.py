"""Deterministic sharded batch pipeline.

Host-side (numpy) iterator producing global batches; on a mesh the launcher
feeds them through jax.device_put with the batch PartitionSpec.  Per-worker
federated sampling matches the paper: each worker holds an i.i.d. local shard
and samples its own minibatch each round; the global batch is the
concatenation ordered by worker index (so batch.reshape(U, -1, ...) recovers
worker locality — the layout per_worker_grads expects).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, Optional

import numpy as np


class FederatedSampler:
    """Round-based sampler over per-worker data shards."""

    def __init__(self, shards: Dict[int, tuple], batch_per_worker: int, seed: int = 0):
        self.shards = shards
        self.bpw = batch_per_worker
        self.rng = np.random.default_rng(seed)

    @property
    def num_workers(self) -> int:
        return len(self.shards)

    def next_round(self) -> Dict[str, np.ndarray]:
        xs, ys = [], []
        for i in range(self.num_workers):
            x, y = self.shards[i]
            idx = self.rng.integers(0, len(x), size=self.bpw)
            xs.append(x[idx])
            ys.append(y[idx])
        return {"x": np.concatenate(xs), "y": np.concatenate(ys)}

    def stack_rounds(self, rounds: int) -> Dict[str, np.ndarray]:
        """Pre-draw `rounds` batches stacked on a leading [R] axis — the input
        layout the compiled scan engines (FLTrainer.run_scan, fl.sweep)
        consume.  Draws from the same RNG stream as repeated next_round()
        calls, so a fresh same-seed sampler replays the identical sequence."""
        draws = [self.next_round() for _ in range(rounds)]
        return {k: np.stack([d[k] for d in draws]) for k in draws[0]}


class TokenBatcher:
    """Iterates [global_batch, seq_len] token batches from a generator fn."""

    def __init__(self, sample_fn: Callable[[int, int], np.ndarray],
                 global_batch: int, seq_len: int, seed: int = 0):
        self.sample_fn = sample_fn
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.sample_fn(self.global_batch, self.seq_len + 1)
        self.step += 1
        return {"tokens": batch}
