"""Synthetic token-stream corpus for LM training (offline container).

A Zipf-distributed Markov stream with planted n-gram structure, so the LM loss
genuinely decreases with training (unlike uniform noise).  Deterministic in
the seed; vocab-size agnostic.
"""
from __future__ import annotations

import numpy as np


def make_markov_tables(vocab: int, seed: int, branch: int = 16):
    """Each token has `branch` likely successors drawn from a Zipf prior."""
    rng = np.random.default_rng(seed)
    zipf_p = 1.0 / np.arange(1, vocab + 1) ** 1.1
    zipf_p /= zipf_p.sum()
    succ = rng.choice(vocab, size=(vocab, branch), p=zipf_p)
    return succ


def stack_token_rounds(rounds: int, n_seqs: int, seq_len: int, vocab: int,
                       seed: int = 0) -> np.ndarray:
    """[rounds, n_seqs, seq_len] int32: one independent Markov batch per FL
    round (round t draws from seed + t), pre-stacked into the [R, ...] batch
    layout the sweep engine consumes.  Stays a numpy array so the chunked
    engine can slice [C, ...] blocks host-side for free."""
    return np.stack([sample_tokens(n_seqs, seq_len, vocab, seed=seed + t)
                     for t in range(rounds)])


def sample_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0) -> np.ndarray:
    """[n_seqs, seq_len] int32 Markov sequences."""
    rng = np.random.default_rng(seed + 1)
    succ = make_markov_tables(vocab, seed)
    out = np.empty((n_seqs, seq_len), np.int32)
    cur = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        out[:, t] = cur
        pick = rng.integers(0, succ.shape[1], size=n_seqs)
        nxt = succ[cur, pick]
        # 10% random restarts keep entropy > 0
        restart = rng.random(n_seqs) < 0.1
        cur = np.where(restart, rng.integers(0, vocab, size=n_seqs), nxt)
    return out
