"""Procedural MNIST-like dataset (the container is offline; see DESIGN.md §7).

Deterministic 28x28 grayscale "digits": each class is a fixed stroke template
(drawn with line segments / arcs on a grid), rendered with random affine
jitter (shift, scale, rotation), stroke thickness and pixel noise.  This gives
a genuinely learnable 10-class problem with MNIST's input dimensionality
(784), so the paper's MLP / convergence experiments transfer directly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

_SIZE = 28

# Stroke templates in a [0,1]^2 coordinate box: list of polylines per digit.
_TEMPLATES = {
    0: [[(0.5, 0.1), (0.8, 0.3), (0.8, 0.7), (0.5, 0.9), (0.2, 0.7), (0.2, 0.3), (0.5, 0.1)]],
    1: [[(0.35, 0.25), (0.55, 0.1), (0.55, 0.9)], [(0.35, 0.9), (0.75, 0.9)]],
    2: [[(0.2, 0.25), (0.5, 0.1), (0.8, 0.3), (0.2, 0.9), (0.8, 0.9)]],
    3: [[(0.2, 0.15), (0.7, 0.15), (0.45, 0.45), (0.8, 0.7), (0.5, 0.92), (0.2, 0.8)]],
    4: [[(0.65, 0.9), (0.65, 0.1), (0.2, 0.6), (0.85, 0.6)]],
    5: [[(0.8, 0.1), (0.25, 0.1), (0.25, 0.5), (0.65, 0.45), (0.8, 0.7), (0.55, 0.92), (0.2, 0.82)]],
    6: [[(0.7, 0.1), (0.35, 0.4), (0.25, 0.75), (0.5, 0.92), (0.75, 0.72), (0.55, 0.5), (0.3, 0.62)]],
    7: [[(0.2, 0.1), (0.8, 0.1), (0.45, 0.9)], [(0.35, 0.5), (0.7, 0.5)]],
    8: [[(0.5, 0.1), (0.75, 0.28), (0.5, 0.48), (0.25, 0.28), (0.5, 0.1)],
        [(0.5, 0.48), (0.8, 0.7), (0.5, 0.92), (0.2, 0.7), (0.5, 0.48)]],
    9: [[(0.75, 0.35), (0.5, 0.5), (0.3, 0.3), (0.5, 0.1), (0.75, 0.25), (0.72, 0.6), (0.5, 0.9)]],
}


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((_SIZE, _SIZE), np.float32)
    # random affine jitter
    ang = rng.uniform(-0.25, 0.25)
    scale = rng.uniform(0.8, 1.1)
    dx, dy = rng.uniform(-0.08, 0.08, size=2)
    ca, sa = np.cos(ang), np.sin(ang)
    thick = rng.uniform(0.7, 1.4)

    def tx(p):
        x, y = p[0] - 0.5, p[1] - 0.5
        x, y = ca * x - sa * y, sa * x + ca * y
        return ((x * scale + 0.5 + dx) * (_SIZE - 1), (y * scale + 0.5 + dy) * (_SIZE - 1))

    yy, xx = np.mgrid[0:_SIZE, 0:_SIZE].astype(np.float32)
    for line in _TEMPLATES[digit]:
        pts = [tx(p) for p in line]
        for (x0, y0), (x1, y1) in zip(pts[:-1], pts[1:]):
            # distance from each pixel to the segment
            vx, vy = x1 - x0, y1 - y0
            ll = max(vx * vx + vy * vy, 1e-6)
            t = np.clip(((xx - x0) * vx + (yy - y0) * vy) / ll, 0.0, 1.0)
            d2 = (xx - (x0 + t * vx)) ** 2 + (yy - (y0 + t * vy)) ** 2
            img = np.maximum(img, np.exp(-d2 / (2.0 * thick**2)))
    img += rng.normal(0.0, 0.05, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_dataset(n: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [n,784] float32 in [0,1], y [n] int32), label-balanced."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, size=n).astype(np.int32)
    x = np.stack([_render(int(d), rng).reshape(-1) for d in y])
    return x, y


def worker_split(x: np.ndarray, y: np.ndarray, num_workers: int,
                 seed: int = 0) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """I.i.d. split across workers (the paper's §II-A assumption)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    shards = np.array_split(perm, num_workers)
    return {i: (x[s], y[s]) for i, s in enumerate(shards)}
