from repro.data.pipeline import (FederatedSampler, TokenBatcher,
                                 dirichlet_worker_split, iter_chunk_blocks)
from repro.data.synthetic_digits import make_dataset, worker_split
from repro.data.text import make_markov_tables, sample_tokens, \
    stack_token_rounds

__all__ = ["FederatedSampler", "TokenBatcher", "dirichlet_worker_split",
           "iter_chunk_blocks",
           "make_dataset", "worker_split", "make_markov_tables",
           "sample_tokens", "stack_token_rounds"]
