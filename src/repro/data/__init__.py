from repro.data.pipeline import (FederatedSampler, TokenBatcher,
                                 dirichlet_worker_split, iter_chunk_blocks)
from repro.data.synthetic_digits import make_dataset, worker_split
from repro.data.text import sample_tokens

__all__ = ["FederatedSampler", "TokenBatcher", "dirichlet_worker_split",
           "iter_chunk_blocks",
           "make_dataset", "worker_split", "sample_tokens"]
