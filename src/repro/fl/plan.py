"""ExecutionPlan: the sweep engine's execution strategy as one validated value.

`SweepEngine` accreted six orthogonal execution knobs across PRs 1-5
(`flat_state`, `strict_numerics`, `mesh`, `grouped_dispatch`, `chunk_rounds`,
`async_staging`); the worker-axis sharding PR adds a seventh
(`worker_shards`).  Every knob changes HOW a sweep executes, never WHAT it
computes — so they belong together in one frozen config object whose
invariants are checked at construction, not deep inside the engine on first
run:

    plan = ExecutionPlan(mesh=make_sweep_mesh(8, worker_shards=4),
                         chunk_rounds=16, async_staging=True)
    SweepEngine(loss_fn, spec, plan=plan)

The legacy per-knob `SweepEngine(...)` kwargs still work — they build a plan
internally and emit a DeprecationWarning — and are pinned bitwise-equal to
the plan path (tests/test_execution_plan.py).

Cross-knob invariants enforced here (same exception types the engine
historically raised, so callers' error handling is unchanged):

  - ``chunk_rounds`` is None or a positive int (ValueError otherwise);
  - ``async_staging`` requires ``chunk_rounds`` (ValueError) — without a
    chunk boundary there is nothing to double-buffer;
  - ``mesh`` requires ``flat_state`` (AssertionError) — only the flat scan
    is shard_mapped;
  - ``mesh`` axis names must be a subset of ("data", "workers", "model")
    in that order, non-empty (AssertionError);
  - ``worker_shards > 1`` requires a mesh carrying a "workers" axis of
    exactly that size (ValueError); left at the default 1 it is derived
    from the mesh, so `ExecutionPlan(mesh=make_sweep_mesh(8,
    worker_shards=4))` alone is enough.
  - ``model_shards > 1`` likewise requires a mesh carrying a "model" axis
    of exactly that size (ValueError), and is derived from the mesh when
    left at 1.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from jax.sharding import Mesh

_SWEEP_MESH_AXES = (("data",), ("workers",), ("data", "workers"),
                    ("model",), ("data", "model"), ("workers", "model"),
                    ("data", "workers", "model"))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """How one compiled sweep executes.  See the `SweepEngine` class
    docstring for each knob's equivalence contract (what stays identical
    across settings, and to what tolerance); this class only owns the
    cross-knob validity rules.

    flat_state      params as one [S, D] matrix across the scan (the warm
                    path); False keeps the PR-1 tree-state reference.
    strict_numerics pin the standardization stats' fp reduction tree so
                    every strategy replays the same trajectory bitwise.
    mesh            optional sweep mesh — "data" shards the lane axis,
                    "workers" the worker axis, "model" the flat-parameter
                    (D) axis; any subset composes, up to the 3-D
                    ("data", "workers", "model") mesh (see
                    `launch.mesh.make_sweep_mesh`).
    grouped_dispatch  static per-defense-family lane partition (vs the
                    per-lane lax.switch reference).
    chunk_rounds    scan-of-chunks execution with [C, ...] batch blocks.
    async_staging   double-buffer the per-chunk host->device staging.
    worker_shards   shard the [S, U, D] slab's worker axis over the mesh's
                    "workers" axis; the OTA combine becomes a psum over
                    worker shards.  Derived from the mesh when left at 1.
    model_shards    shard the flat [S, D] state's (and the slab's) D axis
                    over the mesh's "model" axis — D is zero-padded once,
                    pre-jit, to a multiple of model_shards * TILE_D, and
                    the OTA combine / standardization stats / column-wise
                    screening run shard-local over D (stats psum partial
                    sums; see core.standardize.stats_from_partials).
                    Derived from the mesh when left at 1.
    checkpoint_dir  directory for preemption-safe resume checkpoints: the
                    full resume carry (state, keys, round offset, host-side
                    trajectory blocks) snapshots at chunk boundaries via
                    `repro.checkpoint.save_pytree`, and
                    `SweepEngine.run(..., resume=True)` continues
                    bit-identically to the uninterrupted run.  Requires
                    chunk_rounds (the chunk boundary IS the checkpoint
                    boundary).
    checkpoint_every_chunks
                    snapshot cadence: a checkpoint after every Nth chunk
                    (default 1 = every chunk boundary).  Larger N trades
                    re-computed rounds on resume for less save overhead.
    """

    flat_state: bool = True
    strict_numerics: bool = False
    mesh: Optional[Mesh] = None
    grouped_dispatch: bool = True
    chunk_rounds: Optional[int] = None
    async_staging: bool = False
    worker_shards: int = 1
    checkpoint_dir: Optional[str] = None
    checkpoint_every_chunks: int = 1
    model_shards: int = 1

    def __post_init__(self):
        if self.chunk_rounds is not None and self.chunk_rounds < 1:
            raise ValueError(
                f"chunk_rounds must be a positive int or None, got "
                f"{self.chunk_rounds}")
        if self.async_staging and self.chunk_rounds is None:
            raise ValueError(
                "async_staging double-buffers the per-chunk batch transfers; "
                "it requires chunk_rounds (the monolithic engine consumes "
                "the whole [R, ...] stack in one dispatch, so there is no "
                "chunk boundary to overlap)")
        if self.checkpoint_every_chunks < 1:
            raise ValueError(
                f"checkpoint_every_chunks must be a positive int, got "
                f"{self.checkpoint_every_chunks}")
        if self.checkpoint_dir is not None and self.chunk_rounds is None:
            raise ValueError(
                "checkpoint_dir requires chunk_rounds: the chunk boundary is "
                "the checkpoint boundary (the monolithic engine never "
                "surfaces a mid-run carry to snapshot)")
        if self.checkpoint_every_chunks != 1 and self.checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every_chunks has no effect without "
                "checkpoint_dir")
        if self.mesh is not None:
            assert self.flat_state, \
                "mesh-sharded sweeps require the flat-state path"
            assert self.mesh.axis_names in _SWEEP_MESH_AXES, (
                f'sweep mesh axes must be one of {_SWEEP_MESH_AXES}, '
                f'got {self.mesh.axis_names}')
        mesh_workers = (dict(self.mesh.shape).get("workers", 1)
                        if self.mesh is not None else 1)
        if self.worker_shards == 1 and mesh_workers > 1:
            # Derive the worker-shard count from the mesh so a plan built
            # from make_sweep_mesh(n, worker_shards=W) alone is complete.
            object.__setattr__(self, "worker_shards", mesh_workers)
        if self.worker_shards != 1:
            if self.worker_shards < 1:
                raise ValueError(
                    f"worker_shards must be >= 1, got {self.worker_shards}")
            if not self.flat_state:
                raise ValueError(
                    "worker_shards > 1 requires the flat-state path "
                    "(flat_state=True)")
            if mesh_workers != self.worker_shards:
                raise ValueError(
                    f"worker_shards={self.worker_shards} needs a mesh with a "
                    f'"workers" axis of that size; got '
                    f'{None if self.mesh is None else dict(self.mesh.shape)}')
        mesh_model = (dict(self.mesh.shape).get("model", 1)
                      if self.mesh is not None else 1)
        if self.model_shards == 1 and mesh_model > 1:
            # Same derivation for the model-shard count.
            object.__setattr__(self, "model_shards", mesh_model)
        if self.model_shards != 1:
            if self.model_shards < 1:
                raise ValueError(
                    f"model_shards must be >= 1, got {self.model_shards}")
            if not self.flat_state:
                raise ValueError(
                    "model_shards > 1 requires the flat-state path "
                    "(flat_state=True)")
            if mesh_model != self.model_shards:
                raise ValueError(
                    f"model_shards={self.model_shards} needs a mesh with a "
                    f'"model" axis of that size; got '
                    f'{None if self.mesh is None else dict(self.mesh.shape)}')

    @property
    def data_shards(self) -> int:
        """Lane-axis shard count (1 without a mesh or without a "data" axis)."""
        if self.mesh is None:
            return 1
        return dict(self.mesh.shape).get("data", 1)

    @property
    def worker_sharded(self) -> bool:
        return self.worker_shards > 1

    @property
    def model_sharded(self) -> bool:
        return self.model_shards > 1
