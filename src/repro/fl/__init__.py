from repro.fl.sweep import (
    ScenarioCase,
    SweepEngine,
    SweepResult,
    SweepSpec,
    run_sweep,
)
from repro.fl.trainer import FLTrainer, RoundLog

__all__ = ["FLTrainer", "RoundLog", "ScenarioCase", "SweepEngine",
           "SweepResult", "SweepSpec", "run_sweep"]
