from repro.fl.plan import ExecutionPlan
from repro.fl.sweep import (
    ScenarioCase,
    SweepEngine,
    SweepResult,
    SweepSpec,
    run_sweep,
)
from repro.fl.trainer import FLTrainer, RoundLog

__all__ = ["ExecutionPlan", "FLTrainer", "RoundLog", "ScenarioCase",
           "SweepEngine", "SweepResult", "SweepSpec", "run_sweep"]
