from repro.fl.trainer import FLTrainer, RoundLog

__all__ = ["FLTrainer", "RoundLog"]
