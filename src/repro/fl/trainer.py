"""Federated training loop (the paper's simulation harness, §IV).

One round =
  1. every worker computes a local SGD gradient on its own minibatch,
  2. scalar-stat standardization handshake,
  3. channel draw + power control + (optional) Byzantine attack,
  4. over-the-air aggregation (eq. 7),
  5. PS update w <- w - alpha * gagg (eq. 8).

`mode="floa"` uses the analog path; `mode="digital"` gathers per-worker
gradients and applies a screening defense (median/Krum/...) — the vanilla-FL
comparison the paper argues cannot be done over the air.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as AGG
from repro.core import defenses as DEF
from repro.core.aggregation import FLOAConfig

Array = jax.Array


@dataclasses.dataclass
class RoundLog:
    step: int
    loss: float
    accuracy: Optional[float] = None
    grad_norm: float = 0.0
    wall_s: float = 0.0


@dataclasses.dataclass
class FLTrainer:
    loss_fn: Callable                 # loss_fn(params, batch) -> scalar
    floa: FLOAConfig
    alpha: float                      # raw learning rate (eq. 8)
    mode: str = "floa"                # "floa" | "digital"
    defense: str = "mean"             # digital mode only
    defense_kwargs: Dict = dataclasses.field(default_factory=dict)
    eval_fn: Optional[Callable] = None  # eval_fn(params) -> dict of metrics

    def __post_init__(self):
        floa = self.floa

        def round_step(params, batch, key):
            if self.mode == "floa":
                gagg, aux = AGG.floa_grad(self.loss_fn, params, batch, key, floa)
            else:
                grads_u, _ = AGG.per_worker_grads(
                    self.loss_fn, params, batch, floa.num_workers
                )
                # digital attackers: sign-flip their reported gradients
                if floa.attack.byzantine_mask and floa.attack.attack.value != "none":
                    mask = floa.attack.mask()
                    sgn = jnp.where(mask, -1.0, 1.0)
                    grads_u = jax.tree_util.tree_map(
                        lambda g: g * sgn.reshape((-1,) + (1,) * (g.ndim - 1)).astype(g.dtype),
                        grads_u,
                    )
                gagg = DEF.digital_aggregate(grads_u, self.defense, **self.defense_kwargs)
                aux = {}
            new_params = jax.tree_util.tree_map(
                lambda p, g: (p - self.alpha * g.astype(p.dtype)), params, gagg
            )
            gn = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree_util.tree_leaves(gagg))
            )
            loss = self.loss_fn(new_params, batch)
            return new_params, loss, gn, aux

        self._round_step = jax.jit(round_step)

        def scan_run(params, batches, key):
            def body(carry, batch):
                params, key = carry
                key, sub = jax.random.split(key)
                params, loss, gn, _ = round_step(params, batch, sub)
                return (params, key), (loss, gn)

            (params, _), (loss, gn) = jax.lax.scan(body, (params, key), batches)
            metrics = self.eval_fn(params) if self.eval_fn else {}
            return params, loss, gn, metrics

        self._scan_run = jax.jit(scan_run)
        self._flat_engine = None  # lazy single-lane flat-state sweep engine

    def run(self, params, sampler, rounds: int, key: Array,
            eval_every: int = 25, log_every: int = 0) -> (object, List[RoundLog]):
        logs: List[RoundLog] = []
        for t in range(rounds):
            batch = {k: jnp.asarray(v) for k, v in sampler.next_round().items()}
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            params, loss, gn, _ = self._round_step(params, batch, sub)
            wall = time.perf_counter() - t0
            if eval_every and (t % eval_every == 0 or t == rounds - 1):
                metrics = self.eval_fn(params) if self.eval_fn else {}
                logs.append(RoundLog(
                    step=t, loss=float(loss),
                    accuracy=float(metrics.get("accuracy", np.nan)),
                    grad_norm=float(gn), wall_s=wall,
                ))
                if log_every:
                    print(f"  round {t:4d} loss {float(loss):8.4f} "
                          f"acc {logs[-1].accuracy:.4f}")
        return params, logs

    def run_scan(self, params, batches, key: Array,
                 eval_every: int = 25, flat: bool = False) -> (object, List[RoundLog]):
        """`run` with the round loop compiled into one `jax.lax.scan`.

        batches: pytree of [R, ...] arrays — all rounds' batches stacked up
        front (e.g. `FederatedSampler.stack_rounds(R)`), so the whole run is
        a single dispatch with no per-round Python or host sync.  Keys are
        split round-by-round exactly as in `run`, so on identical inputs the
        trajectories are bit-for-bit identical; only the log schedule
        changes: per-round loss/grad-norm come back as arrays and the final
        params get one eval, so RoundLogs carry the final accuracy only.

        flat=True reuses the sweep engine's flat-state warm path as a
        single-lane sweep: params stay one [D] f32 row across the scan and
        (in FLOA mode) the combine + PS update fuse into `batched_floa_step`.
        In digital mode the lane carries the trainer's screening defense as
        its defense code (core.scenario.DEFENSE_CODES), so the same compiled
        path covers both aggregation families.  Trajectories match the sweep
        engine's lanes exactly; they match this trainer's loop bit-for-bit on
        noiseless channels (the loop draws receiver noise per parameter leaf,
        the flat path draws one [D] row).

        This is the single-scenario surface: one scenario, one program, the
        full [R, ...] batch stack in one dispatch.  Multi-scenario grids,
        mesh sharding, and chunked/async-staged execution live in
        `fl.sweep.SweepEngine` — its class docstring states the equivalence
        contract of every execution knob (flat_state, strict_numerics, mesh,
        grouped_dispatch, chunk_rounds, async_staging).
        """
        rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
        batches = jax.tree_util.tree_map(jnp.asarray, batches)
        if flat:
            defense = self._flat_defense()
            if defense is not None:
                return self._run_scan_flat(params, batches, key, eval_every,
                                           rounds, defense)
            # digital kwargs not expressible as a defense lane (e.g. a
            # custom geometric_median eps): the tree scan below handles them.
        t0 = time.perf_counter()
        params, loss, gn, metrics = self._scan_run(params, batches, key)
        loss, gn = np.asarray(loss), np.asarray(gn)
        wall = (time.perf_counter() - t0) / rounds
        final_acc = float(metrics.get("accuracy", np.nan)) if metrics else np.nan
        logs = [
            RoundLog(step=t, loss=float(loss[t]),
                     accuracy=final_acc if t == rounds - 1 else float("nan"),
                     grad_norm=float(gn[t]), wall_s=wall)
            for t in range(rounds)
            if eval_every and (t % eval_every == 0 or t == rounds - 1)
        ]
        return params, logs

    def _flat_defense(self):
        """DefenseSpec for the flat-scan delegation, or None when the digital
        defense_kwargs cannot be expressed as a sweep lane (e.g. the legacy
        geometric_median eps=... passthrough) — callers then keep the tree
        scan, which forwards arbitrary kwargs to the pytree defense."""
        from repro.core.scenario import DefenseSpec

        if self.mode != "digital":
            return DefenseSpec()
        try:
            return DefenseSpec.from_kwargs(self.defense, **self.defense_kwargs)
        except ValueError:
            return None

    def _run_scan_flat(self, params, batches, key, eval_every, rounds,
                       defense):
        """Single-lane delegation to the sweep engine's flat-state scan."""
        from repro.fl.sweep import ScenarioCase, SweepEngine, SweepSpec

        if self._flat_engine is None:
            spec = SweepSpec.build(
                [ScenarioCase("scan", self.floa, self.alpha,
                              defense=defense)])
            # eval_every=0: final round only, the run_scan log schedule.
            self._flat_engine = SweepEngine(
                self.loss_fn, spec, eval_fn=self.eval_fn, eval_every=0)
        t0 = time.perf_counter()
        res = self._flat_engine.run(params, batches, keys=key[None])
        wall = (time.perf_counter() - t0) / rounds
        acc = res.metrics.get("accuracy")
        final_acc = float(acc[0, -1]) if acc is not None else np.nan
        logs = [
            RoundLog(step=t, loss=float(res.loss[0, t]),
                     accuracy=final_acc if t == rounds - 1 else float("nan"),
                     grad_norm=float(res.grad_norm[0, t]), wall_s=wall)
            for t in range(rounds)
            if eval_every and (t % eval_every == 0 or t == rounds - 1)
        ]
        params_out = jax.tree_util.tree_map(lambda x: x[0], res.params)
        return params_out, logs
