"""Compiled multi-scenario sweep engine: S scenarios x R rounds, one XLA program.

The paper's experimental section (Figs. 1-4) is a grid of scenarios — attack
type x attacker count x power policy x seed — that the looped `FLTrainer.run`
simulates one round-dispatch at a time.  This engine removes both axes of
Python overhead:

  rounds     -> a `jax.lax.scan` body (no per-round dispatch or host sync);
  scenarios  -> a vmapped stacked-`ScenarioParams` axis (one trace, S lanes),
                built by `SweepSpec` from ordinary frozen `FLOAConfig`s.

The lane axis also carries a **defense code** (core.scenario.DEFENSE_CODES):
code 0 lanes take the analog FLOA combine, any other code applies a digital
screening defense (median / trimmed-mean / (multi-)Krum / geometric median)
to the same [S, U, D] per-worker gradient slab — so the full
policy x defense x attack x attacker-count showdown grid is ONE compiled
program, and pure-FLOA sweeps trace no defense kernels at all.  Dispatch is
**grouped** by default: defense codes are concrete config, so the engine
statically partitions the lanes by code (`scenario.build_lane_groups`),
runs each family's kernel once over its contiguous sub-slab, and scatters
results back to lane order — a mixed grid pays only for the families it
contains.  `grouped_dispatch=False` keeps the PR-3 per-lane vmapped
`lax.switch` (which computes every family present for every lane) as the
equivalence reference.  Digital lanes
model Byzantine workers as sign-flipped reported gradients (FLTrainer
mode="digital" semantics) and ignore the channel; their per-worker slab is
the gathered all-gather payload the paper's analog scheme avoids.

The warm path operates on **flat state end-to-end**: parameters are flattened
once to a [S, D] matrix before the scan and stay flat across all rounds.  The
pytree boundary is crossed only inside the loss/grad closure (via a cached
row-unflatten built from one `jax.eval_shape` of the init) and once at the end
of the run — per-worker gradients come off the grad transpose already as one
[S, U, D] block, so the per-round flatten/concat and per-leaf unflatten/update
of the tree-state engine disappear.  The OTA superposition +
de-standardization bias + receiver noise + PS update fuse into one
`batched_floa_step` call (fused batched Pallas kernel on TPU, einsum oracle
elsewhere).  `flat_state=False` keeps the PR-1 tree-state path as the
equivalence reference.

The lane axis is embarrassingly parallel, so it shards: pass `mesh=` (a 1-D
("data",) mesh, e.g. `launch.mesh.make_sweep_mesh()`) and the flat-state scan
is `shard_map`ped over the devices — S is padded to a multiple of the device
count with ghost lanes (replicas of the last scenario) that are dropped from
the results; every real lane's trajectory is unchanged.

The round axis splits too: `chunk_rounds=C` turns the one R-round scan into a
**scan of chunks** — an outer (uncompiled) Python loop over ceil(R/C) chunks
whose inner C-round scan body is the untouched monolithic body, with the
(state, keys, absolute-round-offset) carry threaded through the chunk
boundaries.  Trajectories are unchanged (bit-identical under
`strict_numerics`): the chunk boundary exists for the *input pipeline*, not
the math.  `async_staging=True` double-buffers it — while chunk k executes,
chunk k+1's batch block is sliced host-side (`data.iter_chunk_blocks`, numpy
views) and transferred with an async `jax.device_put`
(`launch.mesh.stage_batch_block`, pre-sharded replicated under a mesh), so
the device never idles waiting on host->device input transfers and the full
[R, ...] batch stack never has to live in device memory.

    spec   = SweepSpec.build([(name, floa_cfg, alpha, seed), ...])
    engine = SweepEngine(loss_fn, spec, eval_fn=...)
    result = engine.run(params0, batches)     # batches: [R, ...] leaves
    result.loss            # [S, R]
    result.metrics["acc"]  # [S, R]

All scenarios share the model init, the per-round batches (the paper's
figures reuse one dataset/sampler across setups), U, and D; everything else —
policy, attack, attacker count/channel, SNR, learning rate, PRNG seed —
varies per scenario.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import channel as CH
from repro.core import defenses as DEF
from repro.core import scenario as SC
from repro.core import standardize as S
from repro.core.aggregation import (
    FLOAConfig,
    batched_floa_combine,
    batched_floa_step,
    flatten_worker_grads,
    per_worker_grads,
)
from repro.core.attacks import DIRECTIONAL_ATTACKS, AttackType
from repro.core.power_control import Policy
from repro.core.scenario import DefenseSpec
from repro.checkpoint import ckpt as CKPT
from repro.data.pipeline import iter_chunk_blocks
from repro.fl.plan import ExecutionPlan
from repro.fl.trainer import RoundLog
from repro.launch.distributed import fetch as _fetch
from repro.launch.mesh import lane_sharding, put_with_sharding, \
    replicated_sharding, stage_batch_block, sweep_state_sharding

Array = jax.Array

# Resume-checkpoint manifest schema version (the `extra` dict written by
# `_save_checkpoint`); bumped when the carry layout changes so a resume
# against a checkpoint from an incompatible engine fails loudly.
_RESUME_VERSION = 1

# Sentinel distinguishing "caller passed this legacy kwarg" from "left at
# default": only explicitly-passed legacy knobs trigger the deprecation
# warning and participate in building the implicit ExecutionPlan.
_UNSET = object()

# Per-round RNG schedule: every lane splits its round subkey into 3 slots
# (0 = channel gains, 1 = receiver noise, 2 = jamming) — UNCHANGED since
# PR 1, so pre-existing scenario codes keep a bitwise-identical key stream.
# The adaptive-adversary axis draws from `fold_in(subkey, const)` side
# channels instead of widening the split:
_FOLD_COLLUDE = 3   # colluding cohort's shared direction
_FOLD_MARKOV = 4    # Gauss-Markov fading innovation
_FOLD_PART = 5      # K-of-U participation mask
_FOLD_H_INIT = 7    # folded on the lane BASE key: stationary h_0 state


@dataclasses.dataclass(frozen=True)
class ScenarioCase:
    """One lane of the sweep: a frozen FLOAConfig plus its lr and PRNG seed.

    defense selects the lane's aggregation rule: the default analog FLOA
    combine ("floa"), or a digital screening defense (median / trimmed-mean /
    Krum / ... — see core.scenario.DEFENSE_CODES) applied to the gathered
    [U, D] gradient slab, with digital attackers modelled as sign-flipped
    reported gradients (the FLTrainer mode="digital" semantics).

    participants selects K-of-U per-round client sampling: each round the
    lane draws K participants from its own key stream (non-participants
    transmit nothing; digital defenses screen the K participating rows
    only).  None (default) is full participation with zero masking ops
    traced; participants=U runs the masked machinery and is pinned bitwise
    equal to None (tests/test_scenario_axes.py).
    """

    name: str
    floa: FLOAConfig
    alpha: float
    seed: int = 0
    defense: DefenseSpec = dataclasses.field(default_factory=DefenseSpec)
    participants: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """An ordered set of scenarios destined for one compiled sweep."""

    cases: Tuple[ScenarioCase, ...]

    @classmethod
    def build(cls, cases: Sequence) -> "SweepSpec":
        """Accepts ScenarioCase instances or (name, floa, alpha[, seed]) tuples."""
        out = []
        for c in cases:
            if not isinstance(c, ScenarioCase):
                c = ScenarioCase(*c)
            out.append(c)
        return cls(cases=tuple(out))

    def __post_init__(self):
        assert self.cases, "empty sweep"
        u = self.cases[0].floa.num_workers
        for c in self.cases:
            c.floa.validate()
            assert c.floa.num_workers == u, "sweep scenarios must share U"
            c.defense.validate(u)
            if c.participants is not None:
                k = c.participants
                if not 1 <= k <= u:
                    raise ValueError(
                        f"lane {c.name!r}: participants={k} invalid for "
                        f"U={u}: need 1 <= K <= U")
                # Digital screening bounds must hold for the K PARTICIPATING
                # rows, not just U (DefenseSpec.validate's bound): the masked
                # kernels screen K rows per round.
                d = c.defense
                if d.name == "trimmed_mean" and not 2 * d.trim < k:
                    raise ValueError(
                        f"lane {c.name!r}: trimmed_mean trim={d.trim} "
                        f"invalid for K={k} participants: need 2*trim < K")
                if d.name in ("krum", "multi_krum"):
                    if d.num_byzantine > k - 3:
                        raise ValueError(
                            f"lane {c.name!r}: krum num_byzantine="
                            f"{d.num_byzantine} invalid for K={k} "
                            f"participants: need f <= K - 3")
                    if d.multi > k:
                        raise ValueError(
                            f"lane {c.name!r}: krum multi={d.multi} invalid "
                            f"for K={k} participants: need multi <= K")
        gm_iters = {c.defense.gm_iters for c in self.cases
                    if c.defense.name == "geometric_median"}
        if len(gm_iters) > 1:  # ValueError like every other defense bound:
            # a bare assert vanishes under -O and a wrong Weiszfeld depth
            # would run silently
            raise ValueError(
                "geometric_median lanes must share gm_iters (it is a static "
                f"scan length, one per compiled sweep); got {sorted(gm_iters)}")

    def __len__(self) -> int:
        return len(self.cases)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.cases)

    @property
    def num_workers(self) -> int:
        return self.cases[0].floa.num_workers

    def stacked_params(self) -> SC.ScenarioParams:
        """Frozen dataclass configs -> traceable struct-of-arrays, [S, ...]."""
        return SC.stack(tuple(
            SC.from_floa(c.floa, c.alpha, c.defense,
                         participants=c.participants)
            for c in self.cases))

    def keys(self) -> Array:
        return jnp.stack([jax.random.PRNGKey(c.seed) for c in self.cases])

    # Static trace decisions: skip the [S, D] RNG draws entirely when no
    # scenario can consume them (EF-only sweeps, noiseless ablations).
    @property
    def any_noise(self) -> bool:
        return any(c.floa.channel.noise_std > 0.0
                   and c.floa.power.policy != Policy.EF for c in self.cases)

    @property
    def any_jamming(self) -> bool:
        return any(c.floa.attack.attack == AttackType.GAUSSIAN
                   and c.floa.attack.num_attackers > 0
                   and c.floa.power.policy != Policy.EF for c in self.cases)

    # Defense-code lane axis (also static trace decisions): a sweep with no
    # digital lanes skips the screening kernels entirely, and a mixed sweep
    # builds its lax.switch over exactly the defense codes present — absent
    # defenses cost nothing under the vmapped select.
    @property
    def any_digital(self) -> bool:
        return any(c.defense.is_digital for c in self.cases)

    @property
    def all_digital(self) -> bool:
        return all(c.defense.is_digital for c in self.cases)

    @property
    def digital_codes(self) -> Tuple[int, ...]:
        return tuple(sorted({c.defense.code for c in self.cases
                             if c.defense.is_digital}))

    @property
    def lane_codes(self) -> Tuple[int, ...]:
        """Per-lane defense codes in lane order — concrete config, which is
        what makes the grouped dispatch a static (build-time) partition."""
        return tuple(c.defense.code for c in self.cases)

    # analog_noise / analog_jamming restrict the any_* trace decisions to the
    # lanes that actually consume the draws: the grouped engine's analog
    # group.  (A digital lane's channel config is dead weight — under the
    # switch dispatch its noise row multiplies into a discarded combine, and
    # an all-zero noise_std row is bitwise inert anyway.)
    @property
    def analog_noise(self) -> bool:
        return any(c.floa.channel.noise_std > 0.0
                   and c.floa.power.policy != Policy.EF
                   and not c.defense.is_digital for c in self.cases)

    @property
    def analog_jamming(self) -> bool:
        return any(c.floa.attack.attack == AttackType.GAUSSIAN
                   and c.floa.attack.num_attackers > 0
                   and c.floa.power.policy != Policy.EF
                   and not c.defense.is_digital for c in self.cases)

    @property
    def gm_iters(self) -> int:
        its = {c.defense.gm_iters for c in self.cases
               if c.defense.name == "geometric_median"}
        return its.pop() if its else 8

    # Adaptive-adversary axis (PR 8) — three more static trace gates.  Each
    # is False for every pre-existing scenario code, so sweeps without the
    # new axes trace the exact program (and key stream) they always did.
    @property
    def any_markov(self) -> bool:
        """Gauss-Markov fading consumers: rho > 0 on an analog, non-EF lane
        (digital lanes ignore the channel; EF ignores |h|).  Gates the
        [S, U, 2] complex-gain scan carry."""
        return any(c.floa.channel.markov_rho > 0.0
                   and c.floa.power.policy != Policy.EF
                   and not c.defense.is_digital for c in self.cases)

    @property
    def any_partial(self) -> bool:
        """K-of-U participation: any lane with participants set.  NOTE an
        explicit participants=U still counts — it runs the masked machinery,
        which is exactly what the K=U == full-participation bitwise contract
        exercises."""
        return any(c.participants is not None for c in self.cases)

    @property
    def any_directional(self) -> bool:
        """COLLUDING/OMNISCIENT cohorts with someone in them, on an analog
        non-EF lane: gates the post-combine rank-1 direction injection."""
        return any(c.floa.attack.attack in DIRECTIONAL_ATTACKS
                   and c.floa.attack.num_attackers > 0
                   and c.floa.power.policy != Policy.EF
                   and not c.defense.is_digital for c in self.cases)


@dataclasses.dataclass
class SweepResult:
    """Per-scenario, per-round trajectories ([S, R] arrays, host-side)."""

    names: Tuple[str, ...]
    params: object                  # final params, leaves [S, ...]
    loss: np.ndarray                # [S, R]
    grad_norm: np.ndarray           # [S, R]
    metrics: Dict[str, np.ndarray]  # each [S, R]

    def index(self, name: str) -> int:
        return self.names.index(name)

    def save(self, path: str) -> str:
        """Serialize to <path>.npz + <path>.meta.json (the
        `repro.checkpoint.write_tree` format, atomic): every params leaf,
        the [S, R] loss/grad_norm trajectories, and each metrics entry as
        exact arrays, with the scenario names in the manifest's `extra` —
        so a resumed or remote sweep can ship its results whole.  Schema
        documented in docs/benchmarks.md.  Returns the payload path."""
        tree = {"params": self.params, "loss": self.loss,
                "grad_norm": self.grad_norm, "metrics": dict(self.metrics)}
        return CKPT.write_tree(path, tree, extra={
            "kind": "SweepResult", "version": 1, "names": list(self.names)})

    @classmethod
    def load(cls, path: str) -> "SweepResult":
        """Inverse of `save`: byte-exact arrays, names, metrics.  The
        params container structure is rebuilt from the recorded tree paths
        (dicts and lists; tuples come back as lists)."""
        tree, meta = CKPT.read_tree(path)
        if meta.get("extra", {}).get("kind") != "SweepResult":
            raise ValueError(
                f"{path!r} is not a saved SweepResult "
                f"(manifest extra.kind={meta.get('extra', {}).get('kind')!r})")
        return cls(names=tuple(meta["extra"]["names"]),
                   params=tree["params"], loss=tree["loss"],
                   grad_norm=tree["grad_norm"],
                   metrics=dict(tree.get("metrics", {})))

    def logs(self, name_or_idx, eval_every: int = 1) -> List[RoundLog]:
        """RoundLog list for one scenario, sampled on the same schedule as
        `FLTrainer.run(eval_every=...)` — drop-in for the figure CSV writers.
        Use the engine's own eval_every here: off-schedule rounds carry NaN
        accuracy (the eval was skipped inside the scan)."""
        i = (name_or_idx if isinstance(name_or_idx, int)
             else self.index(name_or_idx))
        rounds = self.loss.shape[1]
        acc = self.metrics.get("accuracy")
        out = []
        for t in range(rounds):
            if eval_every and (t % eval_every == 0 or t == rounds - 1):
                out.append(RoundLog(
                    step=t, loss=float(self.loss[i, t]),
                    accuracy=(float(acc[i, t]) if acc is not None
                              else float("nan")),
                    grad_norm=float(self.grad_norm[i, t])))
        return out


def _digital_flip(flat: Array, sp: SC.ScenarioParams) -> Array:
    """Digital attackers report -g (the FLTrainer mode="digital" threat
    model — there is no channel to cheat on): sign-flip Byzantine rows of
    the [S, U, D] slab.  Shared by the switch and grouped dispatch paths so
    their per-lane math is identical."""
    sign = jnp.where((sp.attack != 0)[:, None] & sp.byz_mask,
                     jnp.float32(-1.0), jnp.float32(1.0))
    return flat * sign[:, :, None]


def stack_params(params, num: int):
    """Broadcast one init pytree to a stacked [S, ...] scenario axis."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (num,) + x.shape), params)


def make_row_unflatten(template):
    """Cached [D]-row -> params-pytree mapper, from one `jax.eval_shape`.

    template: a single (unstacked) params pytree or matching ShapeDtypeStruct
    tree.  Returns (unflatten_row, sizes) where sizes are the per-leaf entry
    counts in flatten order — the same order `flatten_worker_grads` uses, so
    flatten(unflatten(w)) == w.
    """
    shapes = jax.eval_shape(lambda p: p, template)
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    sizes = tuple(math.prod(l.shape) for l in leaves)

    def unflatten_row(w):
        out, off = [], 0
        for l, n in zip(leaves, sizes):
            out.append(w[off:off + n].reshape(l.shape).astype(l.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    return unflatten_row, sizes


class _WorkerShards:
    """Worker-axis sharding arithmetic for the flat-state scan body.

    Built once per engine (U and the shard count are static); every method
    below runs INSIDE the shard_mapped scan, on one device's slice of the
    ("workers",) mesh axis.  U is ghost-padded up to u_pad = shards * u_loc:
    ghost workers replicate worker U-1's batch rows (finite gradients, no
    NaN poisoning) and carry zero combine coefficients, so they contribute
    exactly nothing to the psum and their stats are sliced away after the
    all-gather.

    RNG discipline: channel gains / coefficients / noise are always drawn at
    the FULL U on every shard (ScenarioParams is replicated), so the key
    consumption schedule — and hence every draw — is identical to the
    unsharded engine's.  Only the gradient slab and its weighted reduction
    are actually distributed.
    """

    def __init__(self, u: int, shards: int):
        self.u = u
        self.shards = shards
        self.u_loc = -(-u // shards)          # ceil: last shard may be ghosts
        self.u_pad = self.u_loc * shards

    def local_batch(self, batch):
        """Gather this shard's workers' rows of the per-round batch:
        [U*b, ...] leaves -> [u_loc*b, ...].  Global worker indices are
        clipped to U-1, so ghost workers recompute worker U-1's gradient
        (discarded — their coefficient column is zeroed in `local_coeff`)."""
        b = jax.tree_util.tree_leaves(batch)[0].shape[0] // self.u
        widx = jax.lax.axis_index("workers")
        gi = jnp.clip(widx * self.u_loc + jnp.arange(self.u_loc), 0, self.u - 1)
        rows = (gi[:, None] * b + jnp.arange(b)[None, :]).reshape(-1)
        return jax.tree_util.tree_map(lambda x: x[rows], batch)

    def gather_slab(self, x: Array) -> Array:
        """[S, u_loc, D] local slab -> [S, U, D] full slab (all-gather over
        "workers"; ghost rows sliced off).  The digital screening defenses
        consume this — they are order statistics over the worker axis, so
        they need the gathered slab the analog scheme avoids."""
        full = jax.lax.all_gather(x, "workers", axis=1, tiled=True)
        return full[:, :self.u]

    def gather_stats(self, gbar_i: Array, eps2_i: Array):
        """Per-worker scalar stats [S, u_loc] -> full [S, U].  All-gathering
        the SCALARS (not the slab) keeps the handshake cheap, and the global
        mean is then reduced from the identical [S, U] vector the unsharded
        engine reduces — same values, same order, bitwise-equal stats."""
        g = jax.lax.all_gather(gbar_i, "workers", axis=1, tiled=True)
        e = jax.lax.all_gather(eps2_i, "workers", axis=1, tiled=True)
        return g[:, :self.u], e[:, :self.u]

    def local_coeff(self, coeff: Array) -> Array:
        """Full [S, U] combine coefficients -> this shard's [S, u_loc] slice,
        ghost columns zero-padded (u_pad = shards * u_loc, so the dynamic
        slice is always in bounds and never clamps across shard boundaries)."""
        pad = self.u_pad - self.u
        if pad:
            coeff = jnp.pad(coeff, ((0, 0), (0, pad)))
        widx = jax.lax.axis_index("workers")
        return jax.lax.dynamic_slice_in_dim(
            coeff, widx * self.u_loc, self.u_loc, axis=1)

    def psum_combine(self, coeff, flat_loc, noise_row, bias_row, eps):
        """The OTA superposition as a psum over worker shards: each shard
        contributes the weighted sum of its own workers' gradients, the
        all-reduce models the multiple-access channel's addition, and the
        (replicated) de-standardization bias + receiver noise land once
        after the reduction — matching `batched_floa_combine`'s reference
        einsum with the U axis distributed."""
        partial = jnp.einsum("su,sud->sd", self.local_coeff(coeff), flat_loc)
        total = jax.lax.psum(partial, "workers")
        return total + bias_row[:, None] + eps[:, None] * noise_row


class _ModelShards:
    """Flat-parameter (D) axis sharding arithmetic for the flat-state scan.

    Built once per compiled program (D comes off the params template); every
    method below runs INSIDE the shard_mapped scan, on one device's column
    block of the ("model",) mesh axis.  D is zero-padded once, pre-jit, to
    d_pad = shards * d_loc with d_loc a multiple of the Pallas TILE_D — the
    "model" split is always even and every shard's column block stays
    kernel-tile aligned.  Ghost columns carry zeros for the whole run: the
    state pads with zeros, the pad region is invisible to the loss (the row
    unflatten reads exactly D entries, so its gradient there is
    structurally zero), the stats' partial sums see exact 0.0
    contributions, and the scan body re-masks the aggregate each round (the
    de-standardization bias is a per-lane scalar broadcast that would
    otherwise smear onto ghost columns).

    RNG discipline: [D]-shaped draws (receiver noise, jamming, the
    colluding cohort's direction) always happen at the FULL real D on every
    shard and are then pad+sliced to the local block — the key consumption
    schedule, and every drawn value, is identical to the unsharded
    engine's (mirroring _WorkerShards' full-U draw rule).
    """

    def __init__(self, d: int, shards: int, tile_d: Optional[int] = None):
        if tile_d is None:
            from repro.kernels.floa_aggregate import TILE_D as tile_d
        self.d = d
        self.shards = shards
        chunk = shards * tile_d
        self.d_pad = -(-d // chunk) * chunk
        self.d_loc = self.d_pad // shards

    def pad_cols(self, x: Array) -> Array:
        """Zero-pad the last (D) axis up to d_pad.  Host- and trace-safe."""
        pad = self.d_pad - x.shape[-1]
        if pad == 0:
            return x
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])

    def local_cols(self, x: Array) -> Array:
        """[..., D or d_pad] full columns -> this shard's [..., d_loc]
        block (zero-padding the real-D tail first, so the last shard's
        ghost columns are exact zeros)."""
        if x.shape[-1] != self.d_pad:
            x = self.pad_cols(x)
        midx = jax.lax.axis_index("model")
        return jax.lax.dynamic_slice_in_dim(
            x, midx * self.d_loc, self.d_loc, axis=x.ndim - 1)

    def gather_cols(self, x: Array) -> Array:
        """[..., d_loc] local block -> [..., D] full REAL columns
        (all-gather over "model"; ghost columns sliced off — they sit at
        the tail of the concatenated blocks, positions D..d_pad-1)."""
        full = jax.lax.all_gather(x, "model", axis=x.ndim - 1, tiled=True)
        return full[..., :self.d]

    def col_mask(self) -> Array:
        """[d_loc] bool: True on this shard's REAL columns.  where(mask,
        x, 0) is a bitwise identity on real columns, so re-masking the
        aggregate never perturbs them."""
        midx = jax.lax.axis_index("model")
        return midx * self.d_loc + jnp.arange(self.d_loc) < self.d


class SweepEngine:
    """Builds (and caches) the jitted scan-over-rounds x vmap-over-scenarios
    program for one (loss_fn, spec, eval_fn) triple.  Reuse the instance to
    amortize compilation across repeated runs (benchmarks, seeds-resampling).

    Execution strategy lives in an `ExecutionPlan` (fl/plan.py) — the
    primary signature is::

        engine = SweepEngine(loss_fn, spec, eval_fn=...,
                             plan=ExecutionPlan(mesh=..., chunk_rounds=...))

    Every plan knob changes HOW the sweep executes, never WHAT it computes;
    each one's equivalence contract (what stays identical, and to what
    tolerance) is stated below and pinned by the test suite.  The plan's
    cross-knob invariants are validated at `ExecutionPlan` construction.
    The pre-plan per-knob constructor kwargs (`flat_state=`, `mesh=`, ...)
    still work: they build the equivalent plan internally (bitwise-equal
    execution, pinned by tests/test_execution_plan.py) and emit a
    DeprecationWarning.  Passing both a plan and legacy kwargs is an error.

    eval_fn / eval_every: run eval_fn only on rounds t with
    t % eval_every == 0 plus the final round (the FLTrainer.run schedule);
    other rounds carry NaN in the metrics arrays.  eval_every <= 0 means
    final round only.  Evaluation happens inside the compiled scan (behind a
    lax.cond), so a sparse schedule skips the eval compute entirely.  The
    schedule is anchored to the ABSOLUTE round index — chunking (below) does
    not move it.

    flat_state=True (default) runs the flat-state warm path: params live as
    one [S, D] f32 matrix for the whole scan and the combine + PS update fuse
    into `batched_floa_step`.  flat_state=False keeps the PR-1 tree-state
    path (per-round flatten/concat + per-leaf update, verbatim by default)
    as the equivalence reference and benchmark baseline.  Contract: the
    paths agree to fp rounding (rtol ~1e-5); constructing BOTH engines with
    strict_numerics=True makes them bit-identical for f32 models.  (The flat
    state is f32; non-f32 leaves are round-tripped through f32 each round,
    matching the flatten that the tree path applies to the gradients.)

    strict_numerics=True pins the standardization stats' fp reduction tree
    (leaf-segmented sums off the materialized [S, U, D] slab, behind an
    optimization barrier) so that every execution strategy — tree vs flat
    state, grouped vs switch dispatch, chunked vs monolithic, sharded vs
    not — replays the same trajectory BIT-FOR-BIT, at the cost of one extra
    pass over the slab per round.  Off (default), XLA may fuse each
    strategy's stats reduction differently and the strategies agree to fp
    rounding only.

    mesh: optional sweep mesh (see `launch.mesh.make_sweep_mesh`) — "data"
    shards the lane axis, "workers" the worker axis, "model" the flat-
    parameter (D) axis; any subset composes, up to the 3-D
    ("data", "workers", "model") mesh.  The flat-state scan is shard_mapped
    over the mesh; with a "data" axis, S is padded up to a multiple of the
    lane-shard count with ghost lanes (replicas of the last scenario) that
    are dropped from the returned SweepResult.  Requires flat_state=True.
    Contract: every real lane's trajectory matches the unsharded engine
    (rtol 1e-6; bitwise in practice and under strict_numerics).

    worker_shards=W > 1 (derived from the mesh's "workers" axis) shards the
    [S, U, D] gradient slab's WORKER axis: each shard computes gradients for
    its own ceil(U/W) workers from its slice of the batch (ghost workers
    replicate worker U-1 and are coefficient-masked to zero), the
    standardization handshake all-gathers per-worker SCALAR stats (so the
    global mean reduces the identical [S, U] vector the unsharded engine
    reduces — bitwise-equal stats), and the OTA combine becomes a
    `lax.psum` of per-shard partial superpositions over the "workers" axis.
    Digital screening lanes all-gather their group's sub-slab first (order
    statistics need the full worker axis).  RNG draws (channel gains,
    coefficients, noise) happen at full U on every shard, so the key
    schedule is the unsharded engine's exactly.  Contract: worker-sharded ==
    unsharded at rtol ~1e-6 per round for any U (including U % W != 0) —
    the psum reduces partial superpositions in mesh order, so multi-round
    float32 trajectories may drift a few ulp past that; under
    strict_numerics the engine all-gathers the full slab up front and
    replays the unsharded reduction order verbatim — bitwise equality, at
    the cost of materializing [S, U, D] per device.

    model_shards=M > 1 (derived from the mesh's "model" axis) shards the
    flat [S, D] state's and the [S, U, D] slab's PARAMETER axis: D is
    zero-padded once, pre-jit, to a multiple of M * TILE_D (ghost columns
    stay exactly zero — see `_ModelShards`), per-worker gradients come off
    all-gathered full-D rows (the grad trace is the unsharded engine's),
    the standardization stats reduce per-shard partial sums with two scalar
    psums per worker (`core.standardize.flat_partial_stats` documents the
    numerical contract), every [D]-shaped RNG draw happens at the full real
    D on every shard (identical key schedule), column-wise screening
    defenses (mean / median / trimmed-mean) run shard-local over their
    column block, row-geometry defenses (Krum family, geometric median)
    all-gather full rows first, and the final unflatten slices the real
    columns back out.  Composes with "data" and "workers" into up-to-3-D
    meshes.  Contract: model-sharded == unsharded at rtol ~1e-6 per round
    (the stats' partial-sum tree reassociates f32 addition); under
    strict_numerics the engine gathers full rows, replays the unsharded
    math verbatim, and re-slices only the carry — bitwise equality.

    grouped_dispatch=True (default) partitions the lanes of a defense-
    carrying sweep by defense code at BUILD time (codes are concrete config):
    lanes are gathered into per-family contiguous groups
    (`scenario.build_lane_groups`), each group's kernel runs once over its
    [S_g, U, D] sub-slab — the analog group keeps the fused
    `batched_floa_step` route, digital groups run exactly their own family —
    and results scatter back to lane order host-side.  A mixed grid thus pays
    only for the families it contains, where the per-lane `lax.switch`
    (grouped_dispatch=False, the PR-3 reference path) computes EVERY family
    present for EVERY lane under vmap.  Under a mesh each group is ghost-
    padded to a multiple of the device count so every shard traces the same
    static group layout.  Pure-FLOA sweeps are untouched by the flag.
    Contract: lane trajectories match the switch path (rtol 1e-6; bitwise
    under strict_numerics) — the per-lane math and key-split schedule are
    shared, only which lanes trace which kernels changes.

    chunk_rounds: None (default) compiles ONE scan over all R rounds.  An
    int C >= 1 switches to scan-of-chunks execution: an outer Python loop
    dispatches ceil(R/C) inner scans of (up to) C rounds each, threading the
    (state, keys, absolute-round-offset) carry through the chunk boundaries
    — RNG key splitting, the eval schedule, metric layout, grouped-dispatch
    lane permutation, and sharded ghost padding are all chunk-invariant.
    Contract: chunked == monolithic at rtol 1e-6 (bitwise under
    strict_numerics) for any C, including R % C != 0 (the last chunk is
    short; it compiles once more for the remainder shape).  The chunk
    boundary exists to bound device batch memory ([C, ...] blocks instead of
    [R, ...]) and to give the input pipeline a place to overlap:

    async_staging=True (requires chunk_rounds) double-buffers the
    host->device batch staging: while chunk k executes, chunk k+1's block is
    sliced host-side (numpy views) and transferred with an async
    `jax.device_put` (`launch.mesh.stage_batch_block`, landing pre-sharded
    replicated under a mesh), so the device never idles on input transfers.
    Contract: a pure scheduling change — results are bit-identical to
    async_staging=False; wins show up on data-bound configs (large batch
    blocks relative to round compute).

    checkpoint_dir (requires chunk_rounds) makes the chunked execution
    preemption-safe: after every checkpoint_every_chunks-th chunk boundary
    (never the final one) the full resume carry — execution-order state
    (including the Markov `h` tuple element when present), the key
    schedule, the absolute round offset, and the host-side
    loss/grad-norm/metric blocks accumulated so far — is written with
    `repro.checkpoint.save_pytree` (atomic: the meta manifest's rename
    commits).  `run(..., resume=True)` restores the latest committed
    snapshot, validates its manifest against this run (rounds, chunking,
    lane names, eval schedule), and dispatches only the remaining chunks.
    Contract: resumed == uninterrupted BITWISE — the restored carry is
    byte-exact and the re-dispatched chunk program is the identical jitted
    computation, so no fp tolerance is needed (pinned across flat/grouped/
    Markov grids and across a SIGKILLed process in
    tests/test_sweep_resume.py).
    """

    def __init__(self, loss_fn: Callable, spec: SweepSpec,
                 eval_fn: Optional[Callable] = None, eval_every: int = 1,
                 plan: Optional[ExecutionPlan] = None,
                 flat_state=_UNSET, mesh=_UNSET, strict_numerics=_UNSET,
                 grouped_dispatch=_UNSET, chunk_rounds=_UNSET,
                 async_staging=_UNSET):
        """See the class docstring for each plan knob's equivalence contract.

        plan: the execution strategy (fl.plan.ExecutionPlan).  The remaining
        kwargs are the deprecated pre-plan spelling: any that are passed
        explicitly build the equivalent plan (DeprecationWarning); mixing
        them with plan= raises.
        """
        legacy = {k: v for k, v in dict(
            flat_state=flat_state, mesh=mesh, strict_numerics=strict_numerics,
            grouped_dispatch=grouped_dispatch, chunk_rounds=chunk_rounds,
            async_staging=async_staging).items() if v is not _UNSET}
        if legacy:
            if plan is not None:
                raise ValueError(
                    f"pass the execution strategy as plan=ExecutionPlan(...) "
                    f"OR as the legacy per-knob kwargs, not both (got plan "
                    f"and {sorted(legacy)})")
            warnings.warn(
                "SweepEngine's per-knob execution kwargs (flat_state, mesh, "
                "strict_numerics, grouped_dispatch, chunk_rounds, "
                "async_staging) are deprecated; pass "
                "plan=ExecutionPlan(...) instead",
                DeprecationWarning, stacklevel=2)
            plan = ExecutionPlan(**legacy)
        elif plan is None:
            plan = ExecutionPlan()
        self.plan = plan
        self.loss_fn = loss_fn
        self.spec = spec
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        # Legacy attribute surface: downstream code (tests, benchmarks)
        # reads the knobs off the engine; keep them as plain mirrors of the
        # plan.
        self.flat_state = plan.flat_state
        self.mesh = plan.mesh
        self.strict_numerics = plan.strict_numerics
        self.grouped_dispatch = plan.grouped_dispatch
        self.chunk_rounds = plan.chunk_rounds
        self.async_staging = plan.async_staging
        self.checkpoint_dir = plan.checkpoint_dir
        self.checkpoint_every_chunks = plan.checkpoint_every_chunks
        self._num = len(spec)
        self._u = spec.num_workers
        self._sp = spec.stacked_params()
        shards = plan.data_shards
        self._ws = (_WorkerShards(self._u, plan.worker_shards)
                    if plan.worker_sharded else None)
        # Model-axis sharding arithmetic is built lazily in _build: the
        # flat parameter count D only arrives with the params template.
        self._ms = None
        # Grouped dispatch only matters when a screening defense shares the
        # grid with other families; pure-FLOA sweeps keep the untouched
        # (unpermuted) fused path regardless of the flag.
        self._groups = (SC.build_lane_groups(spec.lane_codes, shards)
                        if plan.grouped_dispatch and spec.any_digital
                        else None)
        if self._groups is not None:
            self._pad = self._groups.exec_lanes - self._num
            if self._groups.num_ghosts > self._num:
                # Per-group padding to the device count blew the executed
                # lane axis up past 2x: every ghost lane runs (discarded)
                # grads/loss/eval each round, so grouped dispatch can LOSE
                # to the switch path here — say so instead of silently
                # inverting the default's advantage.
                warnings.warn(
                    f"grouped dispatch executes {self._groups.exec_lanes} "
                    f"lanes for {self._num} scenarios ({self._groups.num_ghosts}"
                    f" ghosts: {len(self._groups.codes)} defense-code groups "
                    f"each padded to a multiple of {shards} devices); with "
                    f"groups this small relative to the mesh, "
                    f"grouped_dispatch=False may be faster")
            self._sp_run = SC.permute_lanes(self._sp, self._groups.perm)
        else:
            self._pad = -self._num % shards
            self._sp_run = SC.pad_lanes(self._sp, self._num + self._pad)
        # The compiled program is built lazily on the first run: the flat
        # path needs the params template (leaf shapes/dtypes) to cache its
        # row unflatten, and that only arrives with params0.
        self._run_jit = None
        self._chunk_jit = None
        self._finalize_jit = None
        self._template = None

    # ------------------------------------------------------------ builders

    def _make_digital_select(self):
        """Defense-code lane axis: [S, U, D] slab -> per-lane aggregate select.

        Returns apply(gagg_floa, flat, sp[, part]) -> [S, D]: digital
        attackers' rows are sign-flipped (the FLTrainer mode="digital"
        semantics — a digital Byzantine worker reports -g, it has no channel
        to cheat on), the lane's screening defense runs on the flipped slab
        via a vmapped `lax.switch` over the codes present in the spec, and
        analog lanes (code 0) keep their OTA combine output.  Both state
        paths share this helper so strict_numerics stays bitwise across
        them.  When the spec has participation lanes the selector switches
        over the MASKED kernel table and `part` ([S, U] bool) excludes
        non-participating rows from every screen.
        """
        masked = self.spec.any_partial
        selector = DEF.make_flat_defense_selector(
            self.spec.digital_codes, gm_iters=self.spec.gm_iters,
            masked=masked)

        def apply(gagg_floa, flat, sp: SC.ScenarioParams, part=None):
            flipped = _digital_flip(flat, sp)
            if masked:
                dig = jax.vmap(selector)(sp.defense, flipped, sp.def_trim,
                                         sp.def_f, sp.def_multi, part)
            else:
                dig = jax.vmap(selector)(sp.defense, flipped, sp.def_trim,
                                         sp.def_f, sp.def_multi)
            if gagg_floa is None:  # all-digital sweep: no analog leg at all
                return dig
            return jnp.where((sp.defense == 0)[:, None], gagg_floa, dig)

        return apply

    # ----- grouped dispatch (static lane partition by defense code) -----

    def _digital_group_kernels(self) -> Dict[int, Callable]:
        """code -> single-family [S_g, U, D] kernel, for each digital group
        in the partition (codes are concrete build-time config).  With
        participation lanes in the spec the kernels take the masked form
        (trailing [S_g, U] bool participation argument)."""
        return {code: DEF.make_group_defense_kernel(
                    code, gm_iters=self.spec.gm_iters,
                    masked=self.spec.any_partial)
                for code, _, _ in self._groups.local_slices
                if code != SC._FLOA_CODE}

    # ----- adaptive-adversary axis helpers (PR 8) -----

    def _make_part_draw(self):
        """Per-round K-of-U participation masks, full lane axis: [S, U] bool
        from each lane's fold_in(subkey, _FOLD_PART) side channel — the
        3-way round split is untouched, so non-participation draws are
        unchanged."""
        u = self._u

        def draw(sub_s, sp: SC.ScenarioParams):
            return jax.vmap(lambda k, pk: SC.participation_mask(
                jax.random.fold_in(k, _FOLD_PART), pk, u))(sub_s, sp.part_k)

        return draw

    def _make_markov_update(self):
        """One Gauss-Markov fading step over the full lane axis.

        (h [S, U, 2], sub_s, sp) -> (h_new, h_abs [S, U]).  The legacy
        i.i.d. draw off key slot 0 happens for EVERY lane exactly as before
        (so slots 1/2 — noise/jam — see an identical key stream), and
        rho = 0 lanes keep that draw via the per-lane where: their |h| is
        BITWISE the block-i.i.d. engine's.  rho > 0 lanes take |h| off the
        evolving complex state instead, with innovations from the
        fold_in(subkey, _FOLD_MARKOV) side channel.
        """
        def update(h, sub_s, sp: SC.ScenarioParams):
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(sub_s)
            h_iid = jax.vmap(SC.sample_gains)(ks[:, 0], sp)
            w_in = jax.vmap(lambda k, sg: CH.complex_gain_init(
                jax.random.fold_in(k, _FOLD_MARKOV), sg))(sub_s, sp.sigma)
            h_new = CH.gauss_markov_step(h, w_in, sp.chan_rho[:, None, None])
            h_abs = jnp.where((sp.chan_rho > 0.0)[:, None],
                              CH.complex_gain_abs(h_new), h_iid)
            return h_new, h_abs

        return update

    def _h0_init(self, keys, sp: SC.ScenarioParams):
        """Stationary complex-gain init [S, U, 2] from each lane's BASE key
        (fold_in const _FOLD_H_INIT — the per-round split schedule never
        sees it), so every marginal is Rayleigh(sigma) from round 0."""
        return jax.vmap(lambda k, sg: CH.complex_gain_init(
            jax.random.fold_in(k, _FOLD_H_INIT), sg))(keys, sp.sigma)

    def _make_analog_step(self, ws: Optional[_WorkerShards] = None,
                          grouped: bool = False,
                          ms: Optional[_ModelShards] = None):
        """The analog leg of one round — ONE definition shared by all four
        builders (tree/flat state x grouped/switch dispatch), which is what
        keeps their per-lane math (and the equivalence contracts) aligned.

        step(wg | None, fg, sub_g, spg, gbar_i, eps2_i, part=None,
             h_abs=None) -> (w_new | None, gagg):
        standardization stats + channel draw + power/attack coefficients +
        receiver noise + OTA combine (+ jamming + adaptive rank-1 cohort
        direction) on a [S_g, U, D] (sub-)slab.  With wg given and neither
        jamming nor a directional attack in the spec, the combine and PS
        update stay fused (`batched_floa_step`).  grouped=True narrows the
        noise/jam trace gates to the analog group's lanes (analog_noise /
        analog_jamming).

        part: optional [S_g, U] participation masks — stats then average the
        K participating workers only (`masked_global_stats`, bitwise equal
        to the plain mean at a full mask) and non-participants drop out of
        the coefficients.  h_abs: optional pre-drawn |h| (the Gauss-Markov
        carry path); None draws the legacy block-i.i.d. gains off key
        slot 0.

        With ws (worker sharding, non-strict), fg is the LOCAL
        [S_g, u_loc, D] slice, the draws still happen at full U (replicated
        — identical key schedule), and the combine is `ws.psum_combine`.

        With ms (model sharding, non-strict), fg's LAST axis is the local
        [.., d_loc] column block; every [D]-shaped draw still happens at
        the full real D (identical key schedule) and is pad+sliced local,
        the combine runs on local columns, and the aggregate (and the
        fused route's w_new) is re-masked so ghost columns stay exactly
        zero — the de-standardization bias is a per-lane scalar broadcast
        that would otherwise land on them.
        """
        any_noise = self.spec.analog_noise if grouped else self.spec.any_noise
        any_jam = (self.spec.analog_jamming if grouped
                   else self.spec.any_jamming)
        any_dir = self.spec.any_directional

        def step(wg, fg, sub_g, spg, gbar_i, eps2_i, part=None, h_abs=None):
            n_g = fg.shape[0]
            # [D]-shaped draws happen at the full real D even when fg's
            # columns are a local block (ms) — identical key schedule.
            dim = ms.d if ms is not None else fg.shape[-1]
            if part is None:
                gbar, eps2 = jax.vmap(S.global_stats)(gbar_i, eps2_i)
            else:
                gbar, eps2 = jax.vmap(S.masked_global_stats)(
                    gbar_i, eps2_i, part)
            eps = jnp.sqrt(eps2)
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(sub_g)  # [Sg,3,2]
            if h_abs is None:
                h_abs = jax.vmap(SC.sample_gains)(ks[:, 0], spg)
            args = (h_abs, spg, gbar, eps2)
            if part is not None:
                args = args + (part,)
            coeff, bias_w, jam_std, noise_std, dir_w = jax.vmap(
                SC.scenario_coefficients)(*args)
            if any_noise:
                z = jax.vmap(
                    lambda k: jax.random.normal(k, (dim,), jnp.float32)
                )(ks[:, 1])
                noise_row = noise_std[:, None] * z
                if ms is not None:
                    noise_row = ms.local_cols(noise_row)
            else:
                noise_row = jnp.zeros((n_g, fg.shape[-1]), jnp.float32)
            bias_row = bias_w * gbar
            if ws is not None:
                gagg = ws.psum_combine(coeff, fg, noise_row, bias_row, eps)
            else:
                if wg is not None and not (any_jam or any_dir):
                    w_new, gagg = batched_floa_step(
                        wg, spg.alpha, coeff, fg, noise_row, bias_row, eps)
                    if ms is not None:
                        mask = ms.col_mask()
                        w_new = jnp.where(mask, w_new, 0.0)
                        gagg = jnp.where(mask, gagg, 0.0)
                    return w_new, gagg
                gagg = batched_floa_combine(
                    coeff, fg, noise_row, bias_row, eps)
            if ms is not None:
                # The bias is a per-lane scalar broadcast: re-zero the
                # ghost columns (bitwise identity on real ones).  Every
                # later additive term (jam / direction) is already zero
                # there, so one mask suffices.
                gagg = jnp.where(ms.col_mask(), gagg, 0.0)
            if any_jam:
                n2 = jax.vmap(
                    lambda k: jax.random.normal(k, (dim,), jnp.float32)
                )(ks[:, 2])
                jam_row = jam_std[:, None] * n2
                if ms is not None:
                    jam_row = ms.local_cols(jam_row)
                gagg = gagg + jam_row
            if any_dir:
                # The cohort's shared rank-1 payload, injected after the OTA
                # combine: COLLUDING transmits a cohort-common unit-RMS
                # random direction (fold_in side channel), OMNISCIENT the
                # round's honest (participating) mean gradient; dir_w
                # carries the |h|-weighted received amplitude and is 0.0 for
                # every other attack code.
                d = jax.vmap(lambda k: jax.random.normal(
                    jax.random.fold_in(k, _FOLD_COLLUDE), (dim,),
                    jnp.float32))(sub_g)
                rms = jnp.sqrt(jnp.mean(jnp.square(d), axis=-1,
                                        keepdims=True))
                d = d / jnp.maximum(rms, 1e-20)
                if ms is not None:
                    # Unit-RMS normalization happened at the full real D
                    # (bitwise the unsharded direction); only then slice.
                    d = ms.local_cols(d)
                hmaskf = (~spg.byz_mask).astype(jnp.float32)
                if part is not None:
                    hmaskf = hmaskf * part.astype(jnp.float32)
                cnt = jnp.maximum(jnp.sum(hmaskf, axis=-1), 1.0)
                if ws is not None:
                    hpart = jnp.einsum("su,sud->sd",
                                       ws.local_coeff(hmaskf), fg)
                    hsum = jax.lax.psum(hpart, "workers")
                else:
                    hsum = jnp.einsum("su,sud->sd", hmaskf, fg)
                hm = hsum / cnt[:, None]
                dir_row = jnp.where(
                    (spg.attack == SC._COLLUDING)[:, None], d, hm)
                gagg = gagg + dir_w[:, None] * dir_row
            w_new = None if wg is None else wg - spg.alpha[:, None] * gagg
            return w_new, gagg

        return step

    def _scan_driver(self, one_round, eval_lane, finalize=None,
                     eval_prep=None):
        """Shared scan-over-rounds driver for both state representations.

        Key splitting, the FLTrainer.run eval schedule, and the
        (state, keys, t) carry are identical for the tree- and flat-state
        paths; only the per-round step (`one_round`), the per-lane eval view
        (`eval_lane`, None to skip eval; `eval_prep`, an optional state ->
        eval-rows mapping applied BEFORE the per-lane vmap — the
        model-sharded path gathers full rows there, keeping collectives out
        of the eval cond), and the final state -> stacked params mapping
        (`finalize`) differ.

        Returns (run, scan_chunk, finalize):

          run(state, keys, batches, sp)  — the monolithic program: one scan
              over all R rounds, returning the raw final state (finalize is
              composed OUTSIDE — by `_build`, after any shard_map — so the
              state -> params mapping never has to trace under the mesh).
          scan_chunk(state, keys, t0, rounds_total, batches, sp) — one chunk
              of the scan-of-chunks execution: the SAME scan body over a
              [C, ...] batch block starting at absolute round t0 of
              rounds_total, returning the raw (state, keys) carry for the
              next chunk.  t0/rounds_total are traced int32 scalars, so
              every full-size chunk shares one compile.
          finalize — the final state -> stacked-params mapping (None for the
              tree path, whose state already is the params pytree); applied
              once after the last chunk (or after the monolithic run).

        The monolithic run is scan_chunk at (t0=0, rounds_total=R), so the
        two execution modes share the per-round trace by construction — the
        chunked==monolithic equivalence contract reduces to lax.scan's own
        carry semantics.
        """
        eval_every = self.eval_every

        def eval_maybe(state, t, rounds):
            """eval_lane on the FLTrainer.run schedule; NaN off-schedule.
            The lax.cond skips the eval compute entirely on off-schedule
            rounds.  Metrics are cast to f32 so the NaN sentinel is
            representable (an integer metric would silently read as a
            plausible value).  eval_prep runs OUTSIDE the cond: its
            collectives (the model-sharded full-row gather) must execute
            unconditionally so every mesh shard agrees on the program."""
            if eval_lane is None:
                return {}
            rows = state if eval_prep is None else eval_prep(state)

            def as_f32(s_):
                return jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.float32), jax.vmap(eval_lane)(s_))

            shapes = jax.eval_shape(as_f32, rows)
            blank = jax.tree_util.tree_map(
                lambda s: jnp.full(s.shape, jnp.nan, s.dtype), shapes)
            due = (t == rounds - 1)
            if eval_every > 0:
                due = due | (t % eval_every == 0)
            return jax.lax.cond(due, as_f32, lambda _: blank, rows)

        def scan_chunk(state, keys, t0, rounds_total, batches, sp):
            def body(carry, batch):
                state, keys, t = carry
                split = jax.vmap(jax.random.split)(keys)    # [S, 2, 2]
                keys, subs = split[:, 0], split[:, 1]
                state, loss, gn = one_round(state, batch, subs, sp)
                metrics = eval_maybe(state, t, rounds_total)
                return (state, keys, t + 1), (loss, gn, metrics)

            (state, keys, _), (loss, gn, metrics) = jax.lax.scan(
                body, (state, keys, t0), batches)
            return state, keys, loss, gn, metrics

        def run(state, keys, batches, sp):
            rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
            state, _, loss, gn, metrics = scan_chunk(
                state, keys, jnp.int32(0), jnp.int32(rounds), batches, sp)
            return state, loss, gn, metrics

        return run, scan_chunk, finalize

    def _flat_epilogue(self, unflatten_row, ms: Optional[_ModelShards]):
        """(eval_prep, eval_lane, finalize) for the flat-state builders.

        Without model sharding these are the historical mappings verbatim
        (eval_prep None).  With ms, eval gathers full real-D rows before
        the per-lane vmap (`eval_prep` — the h tuple element, when present,
        is dropped there, which eval never consumed anyway), and finalize —
        which `_build` composes OUTSIDE the shard_map, on the global
        [S, d_pad] state — slices the real columns before unflattening.
        """
        eval_fn = self.eval_fn
        any_markov = self.spec.any_markov
        if ms is not None:
            d = ms.d
            if any_markov:
                eval_prep = lambda st: ms.gather_cols(st[0])
                finalize = lambda st: jax.vmap(unflatten_row)(st[0][:, :d])
            else:
                eval_prep = ms.gather_cols
                finalize = lambda st: jax.vmap(unflatten_row)(st[:, :d])
            eval_lane = (None if eval_fn is None
                         else lambda wr: eval_fn(unflatten_row(wr)))
        elif any_markov:
            eval_prep = None
            eval_lane = (None if eval_fn is None
                         else lambda st: eval_fn(unflatten_row(st[0])))
            finalize = lambda st: jax.vmap(unflatten_row)(st[0])
        else:
            eval_prep = None
            eval_lane = (None if eval_fn is None
                         else lambda wr: eval_fn(unflatten_row(wr)))
            # The only unflatten outside the loss closure: once, at the end.
            finalize = jax.vmap(unflatten_row)
        return eval_prep, eval_lane, finalize

    def _make_run_grouped(self, sizes):
        """Tree-state path with grouped defense dispatch: the per-round
        structure of `_make_run`, but the [S, U, D] slab is processed as
        static per-family groups (lanes pre-gathered into LaneGroups
        execution order) — the analog group's combine and each digital
        family's kernel trace once over their own contiguous sub-slab, and
        the per-lane aggregates concatenate back in group order.  No
        `lax.switch`, no family traced for lanes that don't run it."""
        loss_fn, eval_fn = self.loss_fn, self.eval_fn
        u = self._u
        strict = self.strict_numerics
        local_slices = self._groups.local_slices
        analog_step = self._make_analog_step(grouped=True)
        kernels = self._digital_group_kernels()
        any_markov = self.spec.any_markov
        any_partial = self.spec.any_partial
        markov_update = self._make_markov_update() if any_markov else None
        part_draw = self._make_part_draw() if any_partial else None

        def one_round(state, batch, sub_s, sp: SC.ScenarioParams):
            params_s = state[0] if any_markov else state
            grads = jax.vmap(
                lambda p: per_worker_grads(loss_fn, p, batch, u)[0]
            )(params_s)
            flat, unflatten = flatten_worker_grads(grads, batch_dims=2)
            if strict:
                flat = jax.lax.optimization_barrier(flat)
            num = flat.shape[0]
            if any_markov:
                h_new, h_abs_all = markov_update(state[1], sub_s, sp)
            else:
                h_new, h_abs_all = None, None
            part_all = part_draw(sub_s, sp) if any_partial else None
            parts = []
            for code, start, end in local_slices:
                sl = slice(start, end)
                fg = flat[sl]
                spg = jax.tree_util.tree_map(lambda x: x[sl], sp)
                part_g = None if part_all is None else part_all[sl]
                if code == SC._FLOA_CODE:
                    if strict:
                        gbar_i, eps2_i = jax.vmap(
                            lambda g: S.flat_scalar_stats(g, sizes))(fg)
                    else:
                        grads_g = jax.tree_util.tree_map(
                            lambda x: x[sl], grads)
                        gbar_i, eps2_i = jax.vmap(
                            S.per_worker_scalar_stats)(grads_g)
                    _, gagg_g = analog_step(
                        None, fg, sub_s[sl], spg, gbar_i, eps2_i,
                        part=part_g,
                        h_abs=None if h_abs_all is None else h_abs_all[sl])
                else:
                    flipped = _digital_flip(fg, spg)
                    if any_partial:
                        gagg_g = kernels[code](flipped, spg.def_trim,
                                               spg.def_f, spg.def_multi,
                                               part_g)
                    else:
                        gagg_g = kernels[code](flipped, spg.def_trim,
                                               spg.def_f, spg.def_multi)
                parts.append(gagg_g)
            gagg_flat = jnp.concatenate(parts, axis=0)

            gagg = unflatten(gagg_flat)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - (sp.alpha.reshape((num,) + (1,) * (p.ndim - 1))
                                  * g).astype(p.dtype),
                params_s, gagg)
            gn = jnp.sqrt(jnp.sum(jnp.square(gagg_flat), axis=-1))
            loss = jax.vmap(lambda p: loss_fn(p, batch))(new_params)
            new_state = (new_params, h_new) if any_markov else new_params
            return new_state, loss, gn

        if any_markov:
            eval_lane = (None if eval_fn is None
                         else lambda st: eval_fn(st[0]))
            return self._scan_driver(one_round, eval_lane,
                                     finalize=lambda st: st[0])
        return self._scan_driver(one_round, eval_fn)

    def _make_run_flat_grouped(self, unflatten_row, sizes):
        """Flat-state warm path with grouped defense dispatch.

        The carry stays one [S, D] matrix; per round, each group's lanes
        take exactly their family's compute on a contiguous sub-slab of the
        [S, U, D] gradient block — the analog group keeps the fused
        `batched_floa_step`, digital groups run their kernel and the plain
        PS update — and the per-group (w_new, gagg) slices concatenate back
        in the (static) group order.  Under a mesh the group layout is
        shard-uniform (`build_lane_groups(shards=...)`), so the same static
        slicing serves every device of the shard_mapped scan.
        """
        loss_fn, eval_fn = self.loss_fn, self.eval_fn
        u = self._u
        strict = self.strict_numerics
        local_slices = self._groups.local_slices
        has_analog = any(c == SC._FLOA_CODE for c, _, _ in local_slices)
        # Worker sharding: strict mode all-gathers the full slab up front
        # and replays the unsharded reduction order verbatim (bitwise
        # contract); the default keeps the slab local and distributes the
        # combine as a psum.  Model sharding follows the same rule over the
        # column axis: strict gathers full rows and re-slices only the
        # carry; the default runs the combine / stats / column-wise screens
        # on each shard's local column block.
        ws = self._ws
        ws_run = None if strict else ws
        ms = self._ms
        ms_run = None if strict else ms
        analog_step = self._make_analog_step(ws_run, grouped=True, ms=ms_run)
        kernels = self._digital_group_kernels()
        any_markov = self.spec.any_markov
        any_partial = self.spec.any_partial
        markov_update = self._make_markov_update() if any_markov else None
        part_draw = self._make_part_draw() if any_partial else None

        def flat_loss(w_row, batch):
            return loss_fn(unflatten_row(w_row), batch)

        def one_round(state, batch, sub_s, sp: SC.ScenarioParams):
            w = state[0] if any_markov else state
            if ms is not None:
                # Gradients always come off the FULL real-D rows: the
                # gather reconstructs exactly the unsharded row values, so
                # the per-worker grad trace is the unsharded engine's.
                # Strict mode then keeps everything full-width (re-slicing
                # only the carry at the end — the bitwise contract);
                # the default re-slices the slab to this shard's columns.
                w = ms.gather_cols(w)
            if ws is None:
                grads = jax.vmap(
                    lambda wr: per_worker_grads(flat_loss, wr, batch, u)[0]
                )(w)  # [S, U, D]
            else:
                lb = ws.local_batch(batch)
                grads = jax.vmap(
                    lambda wr: per_worker_grads(flat_loss, wr, lb,
                                                ws.u_loc)[0]
                )(w)  # [S, u_loc, D]
                if strict:
                    grads = ws.gather_slab(grads)
            if ms_run is not None:
                grads = ms.local_cols(grads)
                w = state[0] if any_markov else state  # back to local cols
            if strict and has_analog:
                grads = jax.lax.optimization_barrier(grads)
            if any_markov:
                h_new, h_abs_all = markov_update(state[1], sub_s, sp)
            else:
                h_new, h_abs_all = None, None
            part_all = part_draw(sub_s, sp) if any_partial else None
            w_parts, g_parts = [], []
            for code, start, end in local_slices:
                sl = slice(start, end)
                wg, fg = w[sl], grads[sl]
                spg = jax.tree_util.tree_map(lambda x: x[sl], sp)
                part_g = None if part_all is None else part_all[sl]
                if code == SC._FLOA_CODE:
                    if strict:
                        gbar_i, eps2_i = jax.vmap(
                            lambda g: S.flat_scalar_stats(g, sizes))(fg)
                    elif ms_run is not None:
                        # Shard-local partial sums -> two scalar psums per
                        # worker over "model" (ghost columns contribute
                        # exactly 0.0); the shared epilogue recovers the
                        # full-row stats.  See standardize.flat_partial_stats
                        # for the fp contract (rtol vs the single-sum path).
                        s1, s2 = S.flat_partial_stats(fg)
                        s1 = jax.lax.psum(s1, "model")
                        s2 = jax.lax.psum(s2, "model")
                        gbar_i, eps2_i = S.stats_from_partials(s1, s2, ms.d)
                        if ws_run is not None:
                            gbar_i, eps2_i = ws.gather_stats(gbar_i, eps2_i)
                    else:
                        gbar_i, eps2_i = jax.vmap(
                            lambda g: S.flat_scalar_stats(g))(fg)
                        if ws_run is not None:
                            gbar_i, eps2_i = ws.gather_stats(gbar_i, eps2_i)
                    w_new_g, gagg_g = analog_step(
                        wg, fg, sub_s[sl], spg, gbar_i, eps2_i,
                        part=part_g,
                        h_abs=None if h_abs_all is None else h_abs_all[sl])
                else:
                    fg_full = (ws.gather_slab(fg) if ws_run is not None
                               else fg)
                    # Column-wise screens (mean/median/trimmed-mean) are
                    # per-coordinate over the worker axis, so they run on
                    # the local column block as-is; row-geometry screens
                    # (Krum family, geometric median) score whole rows by
                    # pairwise distance and need the full columns gathered.
                    row_geo = (ms_run is not None
                               and code not in DEF.COLUMNWISE_CODES)
                    if row_geo:
                        fg_full = ms.gather_cols(fg_full)
                    flipped = _digital_flip(fg_full, spg)
                    if any_partial:
                        gagg_g = kernels[code](flipped, spg.def_trim,
                                               spg.def_f, spg.def_multi,
                                               part_g)
                    else:
                        gagg_g = kernels[code](flipped, spg.def_trim,
                                               spg.def_f, spg.def_multi)
                    if row_geo:
                        gagg_g = ms.local_cols(gagg_g)
                    elif ms_run is not None:
                        # Column-wise outputs on all-zero ghost columns are
                        # zero in exact arithmetic; the mask makes the
                        # invariant unconditional (bitwise identity on real
                        # columns).
                        gagg_g = jnp.where(ms.col_mask(), gagg_g, 0.0)
                    w_new_g = wg - spg.alpha[:, None] * gagg_g
                w_parts.append(w_new_g)
                g_parts.append(gagg_g)
            w_new = jnp.concatenate(w_parts, axis=0)
            gagg = jnp.concatenate(g_parts, axis=0)
            if ms_run is not None:
                gn = jnp.sqrt(jax.lax.psum(
                    jnp.sum(jnp.square(gagg), axis=-1), "model"))
                loss = jax.vmap(lambda wr: flat_loss(wr, batch))(
                    ms.gather_cols(w_new))
            else:
                gn = jnp.sqrt(jnp.sum(jnp.square(gagg), axis=-1))
                loss = jax.vmap(lambda wr: flat_loss(wr, batch))(w_new)
            if ms is not None and strict:
                w_new = ms.local_cols(w_new)
            new_state = (w_new, h_new) if any_markov else w_new
            return new_state, loss, gn

        eval_prep, eval_lane, finalize = self._flat_epilogue(
            unflatten_row, ms)
        return self._scan_driver(one_round, eval_lane, finalize=finalize,
                                 eval_prep=eval_prep)

    def _make_run(self, sizes):
        """PR-1 tree-state path: params stay a pytree; every round pays the
        [S, U, D] flatten/concat and a per-leaf unflatten + update.

        By default this is the PR-1 engine verbatim (pytree stats, then
        flatten) — the honest benchmark baseline.  strict_numerics swaps the
        stats for the barrier + leaf-segmented reduction off the flattened
        slab, pinning the fp reduction tree both engines use so the
        flat-state path can match it bitwise."""
        loss_fn, eval_fn = self.loss_fn, self.eval_fn
        u = self._u
        strict = self.strict_numerics
        all_digital = self.spec.all_digital
        digital_select = (self._make_digital_select()
                          if self.spec.any_digital else None)
        analog_step = self._make_analog_step()
        any_markov = self.spec.any_markov
        any_partial = self.spec.any_partial
        markov_update = self._make_markov_update() if any_markov else None
        part_draw = self._make_part_draw() if any_partial else None

        def one_round(state, batch, sub_s, sp: SC.ScenarioParams):
            params_s = state[0] if any_markov else state
            # 1. per-worker local SGD gradients, per scenario: leaves [S, U, ...]
            grads = jax.vmap(
                lambda p: per_worker_grads(loss_fn, p, batch, u)[0]
            )(params_s)
            if any_markov:
                h_new, h_abs = markov_update(state[1], sub_s, sp)
            else:
                h_new, h_abs = None, None
            part = part_draw(sub_s, sp) if any_partial else None

            if all_digital:
                # No analog leg to trace (mirrors the flat-state path, so
                # strict_numerics stays bitwise across representations).
                flat, unflatten = flatten_worker_grads(grads, batch_dims=2)
                num = flat.shape[0]
                gagg_flat = digital_select(None, flat, sp, part)
            else:
                # 2. scalar-stat standardization handshake.
                if strict:
                    # Barrier first: stats reduce from the materialized slab
                    # (needed by the combine anyway), bit-matching the strict
                    # flat-state path.
                    flat, unflatten = flatten_worker_grads(grads, batch_dims=2)
                    flat = jax.lax.optimization_barrier(flat)
                    gbar_i, eps2_i = jax.vmap(
                        lambda g: S.flat_scalar_stats(g, sizes))(flat)
                else:
                    gbar_i, eps2_i = jax.vmap(S.per_worker_scalar_stats)(grads)
                    flat, unflatten = flatten_worker_grads(grads, batch_dims=2)
                num = flat.shape[0]
                # 3+4. channel draw + coefficients + OTA combine (+ jam +
                # directional cohort), the shared analog leg; wg=None keeps
                # the two-step route the tree update needs.
                _, gagg_flat = analog_step(None, flat, sub_s, sp,
                                           gbar_i, eps2_i,
                                           part=part, h_abs=h_abs)
                if digital_select is not None:
                    # Defense lanes override the analog combine with their
                    # screening defense on the same (already materialized) slab.
                    gagg_flat = digital_select(gagg_flat, flat, sp, part)

            # 5. PS update w <- w - alpha * gagg (per-scenario alpha).
            gagg = unflatten(gagg_flat)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - (sp.alpha.reshape((num,) + (1,) * (p.ndim - 1))
                                  * g).astype(p.dtype),
                params_s, gagg)

            gn = jnp.sqrt(jnp.sum(jnp.square(gagg_flat), axis=-1))
            loss = jax.vmap(lambda p: loss_fn(p, batch))(new_params)
            new_state = (new_params, h_new) if any_markov else new_params
            return new_state, loss, gn

        if any_markov:
            eval_lane = (None if eval_fn is None
                         else lambda st: eval_fn(st[0]))
            return self._scan_driver(one_round, eval_lane,
                                     finalize=lambda st: st[0])
        return self._scan_driver(one_round, eval_fn)

    def _make_run_flat(self, unflatten_row, sizes):
        """Flat-state warm path: the carry is one [S, D] f32 matrix.

        The pytree boundary lives inside `flat_loss` only — its grad
        transpose assembles the per-worker gradients straight into the
        [S, U, D] block the combine consumes, and `batched_floa_step` fuses
        the PS update into the same pass, so no per-round concat, unflatten,
        or per-leaf update survives in the compiled scan body.
        """
        loss_fn, eval_fn = self.loss_fn, self.eval_fn
        u = self._u
        strict = self.strict_numerics
        any_jam = self.spec.any_jamming
        any_dir = self.spec.any_directional
        all_digital = self.spec.all_digital
        digital_select = (self._make_digital_select()
                          if self.spec.any_digital else None)
        # Worker sharding: strict mode (and the all-digital short-circuit,
        # whose defenses are order statistics over the full worker axis)
        # all-gathers the slab right after the local gradient pass and then
        # runs the unsharded math verbatim; the default keeps the slab local
        # — scalar stats all-gather, the OTA combine psums.  Model sharding
        # follows the same split over the column axis (the all-digital and
        # mixed-select legs keep full columns for the lax.switch selector —
        # it may contain row-geometry screens — and re-slice its output).
        ws = self._ws
        ws_run = None if strict else ws
        ms = self._ms
        ms_run = None if strict else ms
        analog_step = self._make_analog_step(ws_run, ms=ms_run)
        # Jamming and the directional cohort land AFTER the combine (neither
        # fuses into `batched_floa_step`), and defense lanes select their
        # screening aggregate before the update — those sweeps take the
        # two-step route; pure-FLOA sweeps keep the fused combine + update.
        fused = not (any_jam or any_dir or digital_select is not None
                     or ws_run is not None)
        any_markov = self.spec.any_markov
        any_partial = self.spec.any_partial
        markov_update = self._make_markov_update() if any_markov else None
        part_draw = self._make_part_draw() if any_partial else None

        def flat_loss(w_row, batch):
            return loss_fn(unflatten_row(w_row), batch)

        def one_round(state, batch, sub_s, sp: SC.ScenarioParams):
            w_loc = state[0] if any_markov else state
            # Under model sharding gradients always come off the FULL
            # real-D rows — the gather reconstructs exactly the unsharded
            # row values, so the per-worker grad trace is the unsharded
            # engine's.  `w` below is the width the round's update math
            # runs at: full columns in strict mode (re-slicing only the
            # carry — the bitwise contract), local columns otherwise.
            w_full = ms.gather_cols(w_loc) if ms is not None else w_loc
            w = w_loc if ms_run is not None else w_full
            # 1. per-worker gradients, already flat: [S, U, D] (the local
            # [S, u_loc, D] slice under worker sharding).
            if ws is None:
                grads = jax.vmap(
                    lambda wr: per_worker_grads(flat_loss, wr, batch, u)[0]
                )(w_full)
            else:
                lb = ws.local_batch(batch)
                grads = jax.vmap(
                    lambda wr: per_worker_grads(flat_loss, wr, lb,
                                                ws.u_loc)[0]
                )(w_full)
                if strict or all_digital:
                    grads = ws.gather_slab(grads)
            if any_markov:
                h_new, h_abs = markov_update(state[1], sub_s, sp)
            else:
                h_new, h_abs = None, None
            part = part_draw(sub_s, sp) if any_partial else None

            def outputs(w_new, gagg):
                """gn / loss / carry epilogue, shared by every leg.  With
                local columns the squared norm psums over "model" and the
                loss reads gathered rows; strict model sharding computed
                full-width and re-slices only the carry."""
                if ms_run is not None:
                    gn = jnp.sqrt(jax.lax.psum(
                        jnp.sum(jnp.square(gagg), axis=-1), "model"))
                    loss = jax.vmap(lambda wr: flat_loss(wr, batch))(
                        ms.gather_cols(w_new))
                else:
                    gn = jnp.sqrt(jnp.sum(jnp.square(gagg), axis=-1))
                    loss = jax.vmap(lambda wr: flat_loss(wr, batch))(w_new)
                if ms is not None and strict:
                    w_new = ms.local_cols(w_new)
                new_state = (w_new, h_new) if any_markov else w_new
                return new_state, loss, gn

            # All-digital sweeps skip the analog leg entirely (stats,
            # channel draw, coefficients, combine — their outputs would be
            # discarded by the defense select anyway, and XLA cannot DCE
            # through the per-lane jnp.where).  The selector always sees
            # full columns (grads were never column-sliced on this leg);
            # its output re-slices local, ghost columns exact zeros.
            if all_digital:
                gagg = digital_select(None, grads, sp, part)
                if ms_run is not None:
                    gagg = ms.local_cols(gagg)
                w_new = w - sp.alpha[:, None] * gagg
                return outputs(w_new, gagg)

            if ms_run is not None:
                grads = ms.local_cols(grads)

            # 2. standardization handshake.  strict_numerics pins the fp
            # reduction tree to the tree-state path's (materialization
            # barrier + leaf-segmented sums) so the two engines agree
            # bitwise; the default lets XLA fuse the whole-row reduction
            # into the gradient producer — one less pass over the slab, at
            # the price of ulp-level stat differences.
            if strict:
                grads = jax.lax.optimization_barrier(grads)
                gbar_i, eps2_i = jax.vmap(
                    lambda g: S.flat_scalar_stats(g, sizes))(grads)
            elif ms_run is not None:
                # Shard-local partial sums -> two scalar psums per worker
                # over "model" (ghost columns contribute exactly 0.0); see
                # standardize.flat_partial_stats for the fp contract.
                s1, s2 = S.flat_partial_stats(grads)
                s1 = jax.lax.psum(s1, "model")
                s2 = jax.lax.psum(s2, "model")
                gbar_i, eps2_i = S.stats_from_partials(s1, s2, ms.d)
                if ws_run is not None:
                    gbar_i, eps2_i = ws.gather_stats(gbar_i, eps2_i)
            else:
                gbar_i, eps2_i = jax.vmap(
                    lambda g: S.flat_scalar_stats(g))(grads)
                if ws_run is not None:
                    # Local per-worker scalars -> full [S, U]: the global
                    # mean then reduces the same vector the unsharded
                    # engine reduces (bitwise-equal stats).
                    gbar_i, eps2_i = ws.gather_stats(gbar_i, eps2_i)

            # 3+4(+5). the shared analog leg: channel draw + coefficients +
            # OTA combine (+ jam + directional cohort); with wg given it
            # fuses the PS update too.
            w_new, gagg = analog_step(w if fused else None, grads, sub_s,
                                      sp, gbar_i, eps2_i,
                                      part=part, h_abs=h_abs)
            if not fused:
                if digital_select is not None:
                    slab = (ws.gather_slab(grads) if ws_run is not None
                            else grads)
                    if ms_run is not None:
                        # The switch selector may contain row-geometry
                        # screens: feed it full columns, slice its output,
                        # and merge with the (local) analog aggregate —
                        # replicating the selector's own defense==0 merge.
                        slab = ms.gather_cols(slab)
                        dig = ms.local_cols(
                            digital_select(None, slab, sp, part))
                        gagg = jnp.where((sp.defense == 0)[:, None],
                                         gagg, dig)
                    else:
                        gagg = digital_select(gagg, slab, sp, part)
                w_new = w - sp.alpha[:, None] * gagg

            return outputs(w_new, gagg)

        eval_prep, eval_lane, finalize = self._flat_epilogue(
            unflatten_row, ms)
        return self._scan_driver(one_round, eval_lane, finalize=finalize,
                                 eval_prep=eval_prep)

    def _build(self, template):
        """Compile-cache the run programs (lazy: needs the params template).

        Both execution modes are wrapped here — the monolithic all-R scan
        (`_run_jit`) and the per-chunk scan (`_chunk_jit`, plus the one-shot
        `_finalize_jit` applied after the last chunk) — but jit compiles on
        first call, so an engine only ever pays for the mode it runs."""
        self._template = template
        unflatten_row, sizes = make_row_unflatten(template)
        # Model-shard arithmetic needs D (= sum of the template leaf
        # sizes), so it is born here rather than in __init__.
        self._ms = (_ModelShards(sum(sizes), self.plan.model_shards)
                    if self.plan.model_sharded else None)
        if self.flat_state:
            run, chunk, final = (
                self._make_run_flat_grouped(unflatten_row, sizes)
                if self._groups is not None
                else self._make_run_flat(unflatten_row, sizes))
        else:
            run, chunk, final = (
                self._make_run_grouped(sizes)
                if self._groups is not None else self._make_run(sizes))
        if self.mesh is not None:
            # Prefix specs: lane axis 0 on state/keys/ScenarioParams, lane
            # axis 1 on the [R, S]-stacked scan outputs, batches replicated.
            # A mesh without a "data" axis (pure worker sharding) keeps
            # every operand replicated over the mesh — only the scan body's
            # own all_gather/psum collectives distribute work.  With a
            # "model" axis the flat [S, D(+pad)] state additionally splits
            # its column axis (the Markov `h` tuple element stays
            # lane-only: its worker axis is never column-sharded); loss /
            # grad-norm / metrics come out replicated over "model" — every
            # shard computes them from psummed or gathered full rows.
            has_data = "data" in self.mesh.axis_names
            lane = P("data") if has_data else P()
            lane_t = P(None, "data") if has_data else P()
            rep = P()
            if "model" in self.mesh.axis_names:
                w_spec = P("data" if has_data else None, "model")
                state_spec = ((w_spec, lane) if self.spec.any_markov
                              else w_spec)
            else:
                state_spec = lane
            run = shard_map(
                run, mesh=self.mesh,
                in_specs=(state_spec, lane, rep, lane),
                out_specs=(state_spec, lane_t, lane_t, lane_t),
                check_rep=False)
            # The chunk program additionally threads the raw (state, keys)
            # carry out (lane-sharded) and takes the replicated scalar
            # t0 / rounds_total pair; finalize runs OUTSIDE the shard_map
            # (vmap over lanes, sharding propagates through jit).
            chunk = shard_map(
                chunk, mesh=self.mesh,
                in_specs=(state_spec, lane, rep, rep, rep, lane),
                out_specs=(state_spec, lane, lane_t, lane_t, lane_t),
                check_rep=False)
        if final is None:
            self._run_jit = jax.jit(run)
        else:
            # finalize composes OUTSIDE any shard_map but INSIDE the same
            # jit — it is pure layout (slice/reshape/astype), so the
            # monolithic program's results are unchanged, and under a
            # "model" mesh it sees the global [S, d_pad] state to slice.
            def run_full(state, keys, batches, sp, _run=run, _final=final):
                st, loss, gn, metrics = _run(state, keys, batches, sp)
                return _final(st), loss, gn, metrics

            self._run_jit = jax.jit(run_full)
        self._chunk_jit = jax.jit(chunk)
        self._finalize_jit = None if final is None else jax.jit(final)

    # ----------------------------------------------------- chunked execution

    def _resume_extra(self, rounds: int) -> dict:
        """The validation fingerprint a resume checkpoint carries: every
        quantity the restored carry is only valid for verbatim."""
        return {"resume_version": _RESUME_VERSION,
                "rounds_total": int(rounds),
                "chunk_rounds": int(self.chunk_rounds),
                "exec_lanes": int(self._num + self._pad),
                "eval_every": int(self.eval_every),
                "model_shards": int(self.plan.model_shards),
                "names": list(self.spec.names)}

    def _save_checkpoint(self, t_next, rounds, state, keys,
                         losses, gns, metric_blocks) -> None:
        """Snapshot the full resume carry at a chunk boundary: execution-
        order (permuted/padded) state — the Markov `h` tuple element rides
        along as an ordinary pytree leaf — the key schedule, and the
        host-side trajectory blocks accumulated so far.  Step index =
        rounds completed.  Multi-process: the fetch edge is a COLLECTIVE
        (process_allgather for lane-sharded arrays on a process-spanning
        mesh), so EVERY process builds the host-side tree — only the
        filesystem write is process 0's."""
        tree = {
            "carry": {
                "state": jax.tree_util.tree_map(_fetch, state),
                "keys": _fetch(keys),
            },
            "blocks": {
                "loss": np.concatenate([_fetch(x) for x in losses]),
                "grad_norm": np.concatenate([_fetch(x) for x in gns]),
                "metrics": {
                    k: np.concatenate([_fetch(m[k]) for m in metric_blocks])
                    for k in (metric_blocks[0] if metric_blocks else {})},
            },
        }
        if jax.process_index() != 0:
            return
        extra = self._resume_extra(rounds)
        extra["t_next"] = int(t_next)
        CKPT.save_pytree(self.checkpoint_dir, int(t_next), tree, extra=extra)

    def _restore_checkpoint(self, rounds, state, keys):
        """Load the latest committed resume checkpoint, validate its
        manifest against this engine/run, and refit the saved carry onto
        the freshly-built (state, keys) structures.  Returns
        (t_start, state, keys, losses, gns, metric_blocks) — t_start = 0
        with the fresh carry when no checkpoint exists yet (so
        `resume=True` is safe on the very first launch)."""
        step = CKPT.latest_step(self.checkpoint_dir)
        if jax.process_count() > 1:
            # Only process 0 writes, so its directory view is the
            # authoritative one: broadcast its latest committed step and
            # resume every process from that SAME boundary.  Without this
            # a mid-write race (or a non-shared filesystem) would leave
            # ranks at different t_start, dispatching different numbers
            # of chunk programs and hanging on mismatched collectives.
            from jax.experimental import multihost_utils
            step = int(multihost_utils.broadcast_one_to_all(
                np.int64(-1 if step is None else step)))
            step = None if step < 0 else step
        if step is None:
            return 0, state, keys, [], [], []
        try:
            saved, meta = CKPT.restore_pytree(self.checkpoint_dir, step)
        except FileNotFoundError as e:
            raise FileNotFoundError(
                f"process {jax.process_index()} cannot read resume "
                f"checkpoint step {step} from {self.checkpoint_dir!r}: "
                f"multi-process resume requires checkpoint_dir on a "
                f"filesystem shared by every process (process 0 writes, "
                f"the rest read)") from e
        ex = meta.get("extra", {})
        want = self._resume_extra(rounds)
        got = {k: ex.get(k) for k in want}
        if got != want:
            mismatch = sorted(k for k in want if got[k] != want[k])
            raise ValueError(
                f"resume checkpoint step {step} in "
                f"{self.checkpoint_dir!r} was written by an incompatible "
                f"run: manifest keys {mismatch} differ (checkpoint "
                f"{ {k: got[k] for k in mismatch} } vs engine "
                f"{ {k: want[k] for k in mismatch} })")
        t_start = int(ex["t_next"])
        # Refit the path-rebuilt carry onto this run's exact container
        # structure (tuples — the Markov (w, h) carry — come back from the
        # manifest as lists; leaves are byte-exact, so the refit is purely
        # structural and the resumed trajectory stays bitwise).
        def refit(template, rebuilt):
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template),
                jax.tree_util.tree_leaves(rebuilt))

        state = refit(state, saved["carry"]["state"])
        keys = jnp.asarray(saved["carry"]["keys"])
        if self.mesh is not None:
            lane = lane_sharding(self.mesh)
            if self._ms is not None:
                # Same model-aware placement as run(): the saved carry was
                # fetched at the padded width, so it re-lands column-sharded.
                wsh = sweep_state_sharding(self.mesh)
                if self.spec.any_markov:
                    state = (put_with_sharding(state[0], wsh),
                             put_with_sharding(state[1], lane))
                else:
                    state = put_with_sharding(state, wsh)
            else:
                state = jax.tree_util.tree_map(
                    lambda x: put_with_sharding(x, lane), state)
            keys = put_with_sharding(keys, lane)
        blocks = saved["blocks"]
        return (t_start, state, keys, [blocks["loss"]],
                [blocks["grad_norm"]], [blocks.get("metrics", {})])

    def _run_chunked(self, state, keys, batches, sp, resume: bool = False):
        """Outer loop of the scan-of-chunks execution: dispatch the compiled
        C-round chunk program once per [C, ...] block, thread the
        (state, keys, absolute-round-offset) carry through the boundaries,
        finalize once after the last chunk.

        With async_staging the next block is sliced + `device_put` right
        after the current chunk is dispatched (both are async), so block
        k+1's host->device transfer overlaps chunk k's device compute;
        without it each block is staged synchronously just before its own
        chunk.  Staging order is the ONLY difference between the modes — the
        dispatched programs and operands are identical, so their results
        are bit-identical.

        checkpoint_dir (plan) snapshots the resume carry after every
        checkpoint_every_chunks-th chunk boundary (never after the final
        chunk — the run is about to return); resume=True restores the
        latest snapshot and dispatches only the remaining chunks.  A
        resumed run replays the exact jitted chunk program on a byte-exact
        carry from an on-schedule boundary, so it is bit-identical to the
        uninterrupted run (pinned in tests/test_sweep_resume.py).
        """
        rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
        if rounds == 0:
            # Zero chunks would leave nothing to concatenate; the monolithic
            # program handles the degenerate stack (lax.scan over length-0
            # xs yields empty [0, S] outputs), keeping chunked == monolithic
            # for every input.
            return self._run_jit(state, keys, batches, sp)
        t_start = 0
        losses, gns, metric_blocks = [], [], []
        if resume:
            t_start, state, keys, losses, gns, metric_blocks = \
                self._restore_checkpoint(rounds, state, keys)
        rounds_total = jnp.int32(rounds)
        # Checkpoints land only on chunk boundaries, so t_start is a
        # multiple of chunk_rounds and the remaining blocks slice exactly
        # like the uninterrupted run's (numpy views, nothing copied).
        remaining = jax.tree_util.tree_map(lambda x: x[t_start:], batches)
        blocks = iter_chunk_blocks(remaining, self.chunk_rounds)

        def stage():
            blk = next(blocks, None)
            return (None if blk is None
                    else stage_batch_block(blk, mesh=self.mesh))

        nxt = stage() if self.async_staging else None
        every = self.checkpoint_every_chunks
        for i, t0 in enumerate(range(t_start, rounds, self.chunk_rounds)):
            block = nxt if self.async_staging else stage()
            state, keys, loss, gn, metrics = self._chunk_jit(
                state, keys, jnp.int32(t0), rounds_total, block, sp)
            if self.async_staging:
                nxt = stage()   # overlaps the in-flight chunk dispatched above
            losses.append(loss)
            gns.append(gn)
            metric_blocks.append(metrics)
            t_next = min(t0 + self.chunk_rounds, rounds)
            if (self.checkpoint_dir is not None and t_next < rounds
                    and (i + 1) % every == 0):
                self._save_checkpoint(t_next, rounds, state, keys,
                                      losses, gns, metric_blocks)

        params = (state if self._finalize_jit is None
                  else self._finalize_jit(state))
        # Host-side concat along the round axis: per-chunk outputs are
        # [C, S_exec]; the caller's scatter-back/ghost-drop sees the same
        # [R, S_exec] layout the monolithic scan produces.
        loss = np.concatenate([_fetch(x) for x in losses])
        gn = np.concatenate([_fetch(x) for x in gns])
        metrics = {
            k: np.concatenate([_fetch(m[k]) for m in metric_blocks])
            for k in (metric_blocks[0] if metric_blocks else {})}
        return params, loss, gn, metrics

    # ----------------------------------------------------------------- run

    def run(self, params0, batches, keys: Optional[Array] = None,
            params_stacked: bool = False, resume: bool = False
            ) -> SweepResult:
        """params0: single init pytree, broadcast to all lanes (or pass
        params_stacked=True for leaves already carrying a leading S axis).
        batches: pytree of [R, ...] arrays shared by every scenario.

        resume=True (requires plan.checkpoint_dir) restores the latest
        committed chunk-boundary checkpoint and runs only the remaining
        chunks; the result is bit-identical to the uninterrupted run.  With
        no checkpoint on disk yet it is a fresh run, so a preemptible loop
        can pass resume=True unconditionally.  params0/batches/keys must be
        the original run's (the manifest pins rounds, chunking, lane names,
        and the eval schedule, and raises on mismatch — but the carry can
        only be bitwise-valid for the original inputs)."""
        if resume and self.checkpoint_dir is None:
            raise ValueError(
                "resume=True needs a checkpoint to restore: construct the "
                "engine with plan=ExecutionPlan(checkpoint_dir=..., "
                "chunk_rounds=...)")
        if not params_stacked:
            params0 = stack_params(params0, self._num)
        keys = self.spec.keys() if keys is None else jnp.asarray(keys)
        if self.chunk_rounds is None:
            batches = jax.tree_util.tree_map(jnp.asarray, batches)
        else:
            # Chunked execution stages [C, ...] blocks per chunk; the full
            # [R, ...] stack stays host-side (numpy views slice for free and
            # the device never holds more than ~two blocks).
            batches = jax.tree_util.tree_map(np.asarray, batches)

        template = jax.eval_shape(
            lambda p: jax.tree_util.tree_map(lambda x: x[0], p), params0)
        if self._run_jit is None or template != self._template:
            self._build(template)

        num, total = self._num, self._num + self._pad
        if self.flat_state:
            state, _ = flatten_worker_grads(params0, batch_dims=1)  # [S, D] f32
            if self._ms is not None:
                # Model sharding: zero-pad D to shards * d_loc ONCE, pre-jit;
                # ghost columns stay exactly zero for the whole run (the scan
                # body re-masks every aggregate).  pad_cols acts on the last
                # axis so it commutes with the lane permute/pad below (axis 0).
                state = self._ms.pad_cols(state)
        else:
            state = params0
        if self.spec.any_markov:
            # Gauss-Markov fading: the scan carry grows a [S, U, 2] complex
            # gain state (stationary init off each lane's base key).  Tuples
            # thread through permute/pad/device_put/shard specs unchanged —
            # they are all pytree-prefix operations.
            state = (state, self._h0_init(keys, self._sp))
        if self._groups is not None:
            # Grouped dispatch: gather lanes (and their per-group ghosts)
            # into LaneGroups execution order; results un-permute below.
            state = SC.permute_lanes(state, self._groups.perm)
            keys = SC.permute_lanes(keys, self._groups.perm)
        else:
            if self.flat_state:
                state = SC.pad_lanes(state, total)
            keys = SC.pad_lanes(keys, total)
        sp = self._sp_run

        if self.mesh is not None:
            lane = lane_sharding(self.mesh)
            rep = replicated_sharding(self.mesh)
            if self._ms is not None:
                # The flat [S, d_pad] state splits its column axis over
                # "model"; the Markov h tuple element (no D axis) stays
                # lane-sharded like every other operand.
                wsh = sweep_state_sharding(self.mesh)
                if self.spec.any_markov:
                    state = (put_with_sharding(state[0], wsh),
                             put_with_sharding(state[1], lane))
                else:
                    state = put_with_sharding(state, wsh)
            else:
                state = jax.tree_util.tree_map(
                    lambda x: put_with_sharding(x, lane), state)
            keys = put_with_sharding(keys, lane)
            sp = jax.tree_util.tree_map(
                lambda x: put_with_sharding(x, lane), sp)
            if self.chunk_rounds is None:
                batches = jax.tree_util.tree_map(
                    lambda x: put_with_sharding(x, rep), batches)

        if self.chunk_rounds is None:
            params, loss, gn, metrics = self._run_jit(state, keys, batches, sp)
        else:
            params, loss, gn, metrics = self._run_chunked(
                state, keys, batches, sp, resume=resume)
        if jax.process_count() > 1:
            # Multi-process fetch edge: the jitted outputs are sharded over
            # a process-spanning mesh; all-gather them host-side so every
            # process returns the identical full SweepResult.
            params = jax.tree_util.tree_map(_fetch, params)
            loss, gn = _fetch(loss), _fetch(gn)
            metrics = {k: _fetch(v) for k, v in metrics.items()}

        if self._groups is not None:
            # Scatter back to lane order: pick each source lane's execution
            # row (ghosts are exact replicas; the first occurrence serves).
            inv = np.asarray(self._groups.inverse)
            inv_j = jnp.asarray(inv)

            def lanes(x):  # scan gives [R, S_exec]
                return np.asarray(x).T[inv]

            params_out = jax.tree_util.tree_map(lambda x: x[inv_j], params)
        else:
            def lanes(x):  # scan gives [R, S(+ghosts)]: drop the ghost lanes
                return np.asarray(x).T[:num]

            params_out = jax.tree_util.tree_map(lambda x: x[:num], params)

        return SweepResult(
            names=self.spec.names,
            params=params_out,
            loss=lanes(loss),
            grad_norm=lanes(gn),
            metrics={k: lanes(v) for k, v in metrics.items()},
        )


def run_sweep(loss_fn: Callable, params0, batches, spec: SweepSpec,
              eval_fn: Optional[Callable] = None,
              eval_every: int = 1,
              plan: Optional[ExecutionPlan] = None, *,
              resume: bool = False,
              flat_state=_UNSET,
              mesh=_UNSET,
              chunk_rounds=_UNSET,
              async_staging=_UNSET) -> SweepResult:
    """One-shot convenience wrapper around SweepEngine (see the SweepEngine
    class docstring for each plan knob's equivalence contract)::

        run_sweep(loss_fn, params0, batches, spec,
                  plan=ExecutionPlan(mesh=..., chunk_rounds=...))

    plan= is the execution-strategy signature.  The loose per-knob kwargs
    (flat_state / mesh / chunk_rounds / async_staging) are the deprecated
    pre-plan spelling: any passed explicitly build the equivalent plan
    (bitwise-equal execution, pinned by tests/test_execution_plan.py) and
    emit a DeprecationWarning; mixing them with plan= raises.  Everything
    past plan is keyword-only, so a stray positional argument raises
    instead of silently binding to resume.  resume= forwards to
    `SweepEngine.run` (preemption-safe continuation off
    plan.checkpoint_dir)."""
    legacy = {k: v for k, v in dict(
        flat_state=flat_state, mesh=mesh, chunk_rounds=chunk_rounds,
        async_staging=async_staging).items() if v is not _UNSET}
    if legacy:
        if plan is not None:
            raise ValueError(
                f"pass the execution strategy as plan=ExecutionPlan(...) OR "
                f"as the legacy per-knob kwargs, not both (got plan and "
                f"{sorted(legacy)})")
        warnings.warn(
            "run_sweep's loose execution kwargs (flat_state, mesh, "
            "chunk_rounds, async_staging) are deprecated; pass "
            "plan=ExecutionPlan(...) instead",
            DeprecationWarning, stacklevel=2)
        plan = ExecutionPlan(**legacy)
    elif plan is None:
        plan = ExecutionPlan()
    return SweepEngine(loss_fn, spec, eval_fn=eval_fn,
                       eval_every=eval_every,
                       plan=plan).run(params0, batches, resume=resume)
