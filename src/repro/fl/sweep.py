"""Compiled multi-scenario sweep engine: S scenarios x R rounds, one XLA program.

The paper's experimental section (Figs. 1-4) is a grid of scenarios — attack
type x attacker count x power policy x seed — that the looped `FLTrainer.run`
simulates one round-dispatch at a time.  This engine removes both axes of
Python overhead:

  rounds     -> a `jax.lax.scan` body (no per-round dispatch or host sync);
  scenarios  -> a vmapped stacked-`ScenarioParams` axis (one trace, S lanes),
                built by `SweepSpec` from ordinary frozen `FLOAConfig`s.

Inside the scan body the per-scenario gradient pytrees are flattened to a
single [S, U, D] block and the OTA superposition + de-standardization bias +
receiver noise are applied in one `batched_floa_combine` call, which routes
to the fused batched Pallas kernel on TPU (einsum oracle elsewhere).

    spec   = SweepSpec.build([(name, floa_cfg, alpha, seed), ...])
    engine = SweepEngine(loss_fn, spec, eval_fn=...)
    result = engine.run(params0, batches)     # batches: [R, ...] leaves
    result.loss            # [S, R]
    result.metrics["acc"]  # [S, R]

All scenarios share the model init, the per-round batches (the paper's
figures reuse one dataset/sampler across setups), U, and D; everything else —
policy, attack, attacker count/channel, SNR, learning rate, PRNG seed —
varies per scenario.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scenario as SC
from repro.core import standardize as S
from repro.core.aggregation import (
    FLOAConfig,
    batched_floa_combine,
    flatten_worker_grads,
    per_worker_grads,
)
from repro.core.attacks import AttackType
from repro.core.power_control import Policy
from repro.fl.trainer import RoundLog

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ScenarioCase:
    """One lane of the sweep: a frozen FLOAConfig plus its lr and PRNG seed."""

    name: str
    floa: FLOAConfig
    alpha: float
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """An ordered set of scenarios destined for one compiled sweep."""

    cases: Tuple[ScenarioCase, ...]

    @classmethod
    def build(cls, cases: Sequence) -> "SweepSpec":
        """Accepts ScenarioCase instances or (name, floa, alpha[, seed]) tuples."""
        out = []
        for c in cases:
            if not isinstance(c, ScenarioCase):
                c = ScenarioCase(*c)
            out.append(c)
        return cls(cases=tuple(out))

    def __post_init__(self):
        assert self.cases, "empty sweep"
        u = self.cases[0].floa.num_workers
        for c in self.cases:
            c.floa.validate()
            assert c.floa.num_workers == u, "sweep scenarios must share U"

    def __len__(self) -> int:
        return len(self.cases)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.cases)

    @property
    def num_workers(self) -> int:
        return self.cases[0].floa.num_workers

    def stacked_params(self) -> SC.ScenarioParams:
        """Frozen dataclass configs -> traceable struct-of-arrays, [S, ...]."""
        return SC.stack(tuple(SC.from_floa(c.floa, c.alpha)
                              for c in self.cases))

    def keys(self) -> Array:
        return jnp.stack([jax.random.PRNGKey(c.seed) for c in self.cases])

    # Static trace decisions: skip the [S, D] RNG draws entirely when no
    # scenario can consume them (EF-only sweeps, noiseless ablations).
    @property
    def any_noise(self) -> bool:
        return any(c.floa.channel.noise_std > 0.0
                   and c.floa.power.policy != Policy.EF for c in self.cases)

    @property
    def any_jamming(self) -> bool:
        return any(c.floa.attack.attack == AttackType.GAUSSIAN
                   and c.floa.attack.num_attackers > 0
                   and c.floa.power.policy != Policy.EF for c in self.cases)


@dataclasses.dataclass
class SweepResult:
    """Per-scenario, per-round trajectories ([S, R] arrays, host-side)."""

    names: Tuple[str, ...]
    params: object                  # final params, leaves [S, ...]
    loss: np.ndarray                # [S, R]
    grad_norm: np.ndarray           # [S, R]
    metrics: Dict[str, np.ndarray]  # each [S, R]

    def index(self, name: str) -> int:
        return self.names.index(name)

    def logs(self, name_or_idx, eval_every: int = 1) -> List[RoundLog]:
        """RoundLog list for one scenario, sampled on the same schedule as
        `FLTrainer.run(eval_every=...)` — drop-in for the figure CSV writers.
        Use the engine's own eval_every here: off-schedule rounds carry NaN
        accuracy (the eval was skipped inside the scan)."""
        i = (name_or_idx if isinstance(name_or_idx, int)
             else self.index(name_or_idx))
        rounds = self.loss.shape[1]
        acc = self.metrics.get("accuracy")
        out = []
        for t in range(rounds):
            if eval_every and (t % eval_every == 0 or t == rounds - 1):
                out.append(RoundLog(
                    step=t, loss=float(self.loss[i, t]),
                    accuracy=(float(acc[i, t]) if acc is not None
                              else float("nan")),
                    grad_norm=float(self.grad_norm[i, t])))
        return out


def stack_params(params, num: int):
    """Broadcast one init pytree to a stacked [S, ...] scenario axis."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (num,) + x.shape), params)


class SweepEngine:
    """Builds (and caches) the jitted scan-over-rounds x vmap-over-scenarios
    program for one (loss_fn, spec, eval_fn) triple.  Reuse the instance to
    amortize compilation across repeated runs (benchmarks, seeds-resampling)."""

    def __init__(self, loss_fn: Callable, spec: SweepSpec,
                 eval_fn: Optional[Callable] = None, eval_every: int = 1):
        """eval_every: run eval_fn only on rounds t with t % eval_every == 0
        plus the final round (the FLTrainer.run schedule); other rounds carry
        NaN in the metrics arrays.  eval_every <= 0 means final round only.
        Evaluation happens inside the compiled scan, so a sparse schedule
        skips the eval compute entirely."""
        self.loss_fn = loss_fn
        self.spec = spec
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        self._num = len(spec)
        self._u = spec.num_workers
        self._sp = spec.stacked_params()
        self._run_jit = jax.jit(self._make_run())

    def _make_run(self):
        loss_fn, eval_fn = self.loss_fn, self.eval_fn
        eval_every = self.eval_every
        u, num = self._u, self._num
        any_noise = self.spec.any_noise
        any_jam = self.spec.any_jamming

        def one_round(params_s, batch, sub_s, sp: SC.ScenarioParams):
            # 1. per-worker local SGD gradients, per scenario: leaves [S, U, ...]
            grads = jax.vmap(
                lambda p: per_worker_grads(loss_fn, p, batch, u)[0]
            )(params_s)

            # 2. scalar-stat standardization handshake.
            gbar_i, eps2_i = jax.vmap(S.per_worker_scalar_stats)(grads)
            gbar, eps2 = jax.vmap(S.global_stats)(gbar_i, eps2_i)
            eps = jnp.sqrt(eps2)

            # 3. channel draw + power control + attack, branchless per lane.
            flat, unflatten = flatten_worker_grads(grads, batch_dims=2)
            dim = flat.shape[-1]
            ks = jax.vmap(lambda k: jax.random.split(k, 3))(sub_s)  # [S, 3, 2]
            h_abs = jax.vmap(SC.sample_gains)(ks[:, 0], sp)
            coeff, bias_w, jam_std, noise_std = jax.vmap(
                SC.scenario_coefficients
            )(h_abs, sp, gbar, eps2)

            # 4. OTA superposition + bias + receiver AWGN, one fused combine.
            if any_noise:
                z = jax.vmap(
                    lambda k: jax.random.normal(k, (dim,), jnp.float32)
                )(ks[:, 1])
                noise_row = noise_std[:, None] * z
            else:
                noise_row = jnp.zeros((num, dim), jnp.float32)
            gagg_flat = batched_floa_combine(
                coeff, flat, noise_row, bias_w * gbar, eps)
            if any_jam:  # GAUSSIAN ablation: unstructured max-power jamming
                n2 = jax.vmap(
                    lambda k: jax.random.normal(k, (dim,), jnp.float32)
                )(ks[:, 2])
                gagg_flat = gagg_flat + jam_std[:, None] * n2

            # 5. PS update w <- w - alpha * gagg (per-scenario alpha).
            gagg = unflatten(gagg_flat)
            new_params = jax.tree_util.tree_map(
                lambda p, g: p - (sp.alpha.reshape((num,) + (1,) * (p.ndim - 1))
                                  * g).astype(p.dtype),
                params_s, gagg)

            gn = jnp.sqrt(jnp.sum(jnp.square(gagg_flat), axis=-1))
            loss = jax.vmap(lambda p: loss_fn(p, batch))(new_params)
            return new_params, loss, gn

        def eval_maybe(params_s, t, rounds):
            """eval_fn on the FLTrainer.run schedule; NaN off-schedule.  The
            lax.cond skips the eval compute entirely on off-schedule rounds.
            Metrics are cast to f32 so the NaN sentinel is representable
            (an integer metric would silently read as a plausible value)."""
            if eval_fn is None:
                return {}

            def as_f32(p):
                return jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.float32), jax.vmap(eval_fn)(p))

            shapes = jax.eval_shape(as_f32, params_s)
            blank = jax.tree_util.tree_map(
                lambda s: jnp.full(s.shape, jnp.nan, s.dtype), shapes)
            due = (t == rounds - 1)
            if eval_every > 0:
                due = due | (t % eval_every == 0)
            return jax.lax.cond(due, as_f32, lambda _: blank, params_s)

        def run(params_s, keys, batches):
            rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]

            def body(carry, batch):
                params_s, keys, t = carry
                split = jax.vmap(jax.random.split)(keys)    # [S, 2, 2]
                keys, subs = split[:, 0], split[:, 1]
                params_s, loss, gn = one_round(params_s, batch, subs, self._sp)
                metrics = eval_maybe(params_s, t, rounds)
                return (params_s, keys, t + 1), (loss, gn, metrics)

            (params_s, _, _), (loss, gn, metrics) = jax.lax.scan(
                body, (params_s, keys, jnp.int32(0)), batches)
            return params_s, loss, gn, metrics

        return run

    def run(self, params0, batches, keys: Optional[Array] = None,
            params_stacked: bool = False) -> SweepResult:
        """params0: single init pytree, broadcast to all lanes (or pass
        params_stacked=True for leaves already carrying a leading S axis).
        batches: pytree of [R, ...] arrays shared by every scenario."""
        if not params_stacked:
            params0 = stack_params(params0, self._num)
        keys = self.spec.keys() if keys is None else keys
        batches = jax.tree_util.tree_map(jnp.asarray, batches)
        params, loss, gn, metrics = self._run_jit(params0, keys, batches)
        return SweepResult(
            names=self.spec.names,
            params=params,
            loss=np.asarray(loss).T,            # scan gives [R, S]
            grad_norm=np.asarray(gn).T,
            metrics={k: np.asarray(v).T for k, v in metrics.items()},
        )


def run_sweep(loss_fn: Callable, params0, batches, spec: SweepSpec,
              eval_fn: Optional[Callable] = None,
              eval_every: int = 1) -> SweepResult:
    """One-shot convenience wrapper around SweepEngine."""
    return SweepEngine(loss_fn, spec, eval_fn=eval_fn,
                       eval_every=eval_every).run(params0, batches)
