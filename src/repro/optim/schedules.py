"""Learning-rate schedules (jit-safe functions of the step index)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.float32(lr * (final_frac + (1 - final_frac) * cos))

    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    base = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, jnp.float32(lr) * w, base(step - warmup))

    return fn
