"""Minimal functional optimizers (no optax in the container).

Each optimizer is (init_fn, update_fn):
  state = init_fn(params)
  updates, state = update_fn(grads, state, params, lr)
  params = apply_updates(params, updates)
The paper's FLOA update (eq. 8) is plain SGD on the noisy aggregate.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates,
    )


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        if momentum == 0.0:
            upd = jax.tree_util.tree_map(lambda g: -lr * g.astype(jnp.float32), grads)
            return upd, state
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads
        )
        upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        return dict(
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params),
            t=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, lr):
        t = state["t"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def u(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        upd = jax.tree_util.tree_map(u, mu, nu, params)
        return upd, dict(mu=mu, nu=nu, t=t)

    return Optimizer(init, update)
