from repro.optim.optimizers import adamw, apply_updates, sgd
from repro.optim.schedules import constant, cosine, warmup_cosine

__all__ = ["sgd", "adamw", "apply_updates", "constant", "cosine", "warmup_cosine"]
