import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun   # orchestrates
                                                             # subprocesses
Single-combo mode runs in-process and writes one JSON; --all spawns one
subprocess per combo (isolates compile memory, survives individual failures).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step


def _reduced_layers(cfg, units: int):
    """Config with `units` scan repeats, fully unrolled for analysis (XLA
    cost analysis counts while-loop bodies once, so probes must be loop-free —
    layers, attention q-chunks, SSD chunks and expert scans all unroll)."""
    import dataclasses
    if cfg.encdec is not None:
        ed = dataclasses.replace(cfg.encdec, n_enc_layers=units,
                                 n_dec_layers=units)
        return dataclasses.replace(cfg, encdec=ed, n_layers=2 * units,
                                    unroll_for_analysis=True)
    return dataclasses.replace(cfg, n_layers=units * len(cfg.block_pattern),
                               unroll_for_analysis=True)


def _units_full(cfg) -> float:
    if cfg.encdec is not None:
        return float(cfg.encdec.n_enc_layers)  # enc & dec probed together
    return cfg.n_layers / len(cfg.block_pattern)


def _measure(cfg, mesh, shape_name, shape):
    art = make_step(cfg, mesh, shape_name, shape)
    with mesh:
        compiled = jax.jit(art.fn, in_shardings=art.in_shardings).lower(
            *art.args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = HA.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def probe_costs(cfg, mesh, shape_name, shape):
    """XLA counts while-loop bodies ONCE (known_trip_count is metadata only),
    so per-layer costs are extrapolated from 1-unit and 2-unit probes:
    f(L) = base + L*body, with body = f(2)-f(1).  Exact for the homogeneous
    scanned stacks; the few tail blocks are attributed at body-unit rate."""
    f1, b1, c1 = _measure(_reduced_layers(cfg, 1), mesh, shape_name, shape)
    f2, b2, c2 = _measure(_reduced_layers(cfg, 2), mesh, shape_name, shape)
    n = _units_full(cfg)

    def ext(v1, v2):
        body = v2 - v1
        return max(v1 - body, 0.0) + body * n

    coll = {k: ext(c1[k], c2[k]) for k in c1}
    return ext(f1, f2), ext(b1, b2), coll


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    cfg = get_config(arch, model_parallel=16)
    shape = INPUT_SHAPES[shape_name]
    if not shape_applicable(cfg, shape_name):
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind, status="skip",
                   reason=f"{arch} skips {shape_name} (see DESIGN.md §5)")
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    art = make_step(cfg, mesh, shape_name, shape)
    with mesh:
        lowered = jax.jit(
            art.fn, in_shardings=art.in_shardings
        ).lower(*art.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    raw_coll = HA.collective_bytes(hlo)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))

    # layer-extrapolated costs (XLA counts while bodies once; see probe_costs).
    # The §Roofline table is single-pod by spec, so multi-pod combos skip the
    # (expensive, unrolled) probes — they prove lower+compile+memory only.
    if mesh_kind == "single":
        flops, nbytes, coll = probe_costs(cfg, mesh, shape_name, shape)
    else:
        flops, nbytes, coll = raw_flops, raw_bytes, raw_coll
    terms = HA.roofline_terms(flops, nbytes, coll["total"])
    n_params = art.meta["dim"]
    n_active = HA.active_params(cfg, n_params)
    mflops = HA.model_flops(cfg, shape, n_params, n_active)
    chips = mesh.devices.size

    rec = dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, status="ok",
        chips=chips,
        n_params=n_params, n_active=n_active,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops_per_device=flops, bytes_per_device=nbytes,
        raw_module=dict(flops=raw_flops, bytes=raw_bytes,
                        collectives=raw_coll),
        collectives=coll,
        roofline=terms,
        dominant=HA.dominant(terms),
        model_flops=mflops,
        model_flops_per_device=mflops / chips,
        useful_ratio=(mflops / chips) / flops if flops else None,
        memory=dict(
            argument_size=getattr(mem, "argument_size_in_bytes", None),
            output_size=getattr(mem, "output_size_in_bytes", None),
            temp_size=getattr(mem, "temp_size_in_bytes", None),
            generated_code_size=getattr(mem, "generated_code_size_in_bytes", None),
        ),
        meta=art.meta,
    )
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    dom = rec.get("dominant", "-")
    print(f"[dryrun] {rec['arch']:28s} {rec['shape']:12s} {rec['mesh']:6s} "
          f"{rec['status']:4s} dominant={dom} "
          f"compile={rec.get('compile_s', '-')}s", flush=True)


def orchestrate(out_dir: str, meshes, archs, shapes, timeout: int) -> int:
    fails = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
                if os.path.exists(path):
                    continue  # resumable
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                       "--out", out_dir]
                try:
                    r = subprocess.run(cmd, timeout=timeout,
                                       capture_output=True, text=True)
                    if r.returncode != 0:
                        fails += 1
                        err = (r.stdout + r.stderr)[-3000:]
                        with open(path, "w") as f:
                            json.dump(dict(arch=arch, shape=shape,
                                           mesh=mesh_kind, status="fail",
                                           error=err), f, indent=1)
                        print(f"[dryrun] FAIL {arch} {shape} {mesh_kind}:\n{err}",
                              flush=True)
                    else:
                        print(r.stdout.strip().splitlines()[-1]
                              if r.stdout.strip() else "", flush=True)
                except subprocess.TimeoutExpired:
                    fails += 1
                    with open(path, "w") as f:
                        json.dump(dict(arch=arch, shape=shape, mesh=mesh_kind,
                                       status="timeout"), f, indent=1)
                    print(f"[dryrun] TIMEOUT {arch} {shape} {mesh_kind}",
                          flush=True)
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.all:
        archs = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
        fails = orchestrate(args.out, args.meshes.split(","), archs, shapes,
                            args.timeout)
        sys.exit(1 if fails else 0)

    assert args.arch and args.shape
    try:
        run_one(args.arch, args.shape, args.mesh, args.out)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
