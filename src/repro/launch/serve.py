"""Serving driver: batched prefill + decode on a mesh.

  python -m repro.launch.serve --arch starcoder2-3b --smoke --mesh 4x2 \
      --batch 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import get_config, get_smoke
from repro.data import sample_tokens
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import init_model, make_decode_step
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        mp = 16
    else:
        r, c = map(int, args.mesh.split("x"))
        mesh = make_debug_mesh((r, c), ("data", "model"))
        mp = c
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, model_parallel=mp)
    assert cfg.arch_type != "audio", "use encdec serve path (examples/)"

    max_len = args.prompt_len + args.gen
    shape = dict(seq_len=max_len, global_batch=args.batch, kind="decode")
    art = make_decode_step(cfg, mesh, shape, "decode_32k")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    prompts = jnp.asarray(sample_tokens(args.batch, args.prompt_len,
                                        vocab=cfg.vocab_size, seed=0))
    caches = T.init_caches(cfg, args.batch, max_len, window=cfg.window)

    with mesh:
        step_fn = jax.jit(art.fn, in_shardings=art.in_shardings)
        # prefill by decoding the prompt (cache-building pass)
        t0 = time.perf_counter()
        tok = prompts[:, :1]
        for i in range(args.prompt_len):
            logits, caches = step_fn(params, caches, prompts[:, i:i + 1],
                                     jnp.int32(i))
        prefill_s = time.perf_counter() - t0
        # generate
        key = jax.random.PRNGKey(7)
        out = []
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        t0 = time.perf_counter()
        for i in range(args.prompt_len, max_len):
            out.append(tok)
            logits, caches = step_fn(params, caches, tok, jnp.int32(i))
            lg = logits[:, :, :cfg.vocab_size]
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, lg / args.temperature,
                                             axis=-1).astype(jnp.int32)
            else:
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        gen_s = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prefill={prefill_s:.2f}s "
          f"decode={gen_s:.2f}s ({args.batch * args.gen / gen_s:.1f} tok/s)")
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
