"""Distributed step builders: FLOA train_step, prefill_step, serve (decode)
step, per (architecture x input shape x mesh).

The FLOA train step realizes the paper's eq. (6)-(8) in ONE pjit'd backward
pass via the weighted-loss identity

    sum_i s_i * grad L_i  ==  grad ( sum_i s_i L_i ),

where worker i = data-shard i of the global batch and s_i is the signed
received coefficient (power x channel gain, sign-flipped for Byzantine
workers, Thm 1).  The resulting gradient reduction over the "data" axis IS
the over-the-air superposition — XLA lowers it to the reduce-scatter/
all-reduce the roofline's collective term measures.  De-standardization bias
(eq. 7, third term) and receiver AWGN (eps_t * z, sharded draw) are added to
the aggregate, then SGD applies it (eq. 8).

Scalar standardization stats: at ZeRO-3 scale no device can hold per-worker
gradients, so the (gbar_t, eps_t) pair the attacker model and noise scaling
consume is a one-round-stale EMA estimated from the aggregate (documented in
DESIGN.md §7; the paper-exact fresh-stats path lives in repro.core.aggregation
and is validated against the paper's claims in tests/benchmarks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import attacks as ATK
from repro.core.attacks import AttackConfig, AttackType, first_n_mask
from repro.core.channel import ChannelConfig, noise_std_for_snr, sample_channel_gains
from repro.core.power_control import Policy, PowerConfig
from repro.launch.mesh import batch_axes, model_parallel, num_workers
from repro.launch.sharding import (
    cache_specs,
    fsdp_augment,
    make_constrain,
    make_constrain_logits,
    to_shardings,
)
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.common import (
    ModelConfig,
    reset_sharding_context,
    set_sharding_context,
)

Array = jax.Array
SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# model abstraction (decoder-only LM vs encoder-decoder)
# ---------------------------------------------------------------------------


def init_model(cfg: ModelConfig, key, shape_only: bool = False):
    if cfg.arch_type == "audio":
        return ED.init_encdec(key, cfg, shape_only=shape_only)
    return T.init_lm(key, cfg, shape_only=shape_only)


def param_count(params_shape) -> int:
    return sum(int(math.prod(x.shape)) for x in jax.tree_util.tree_leaves(params_shape))


def batch_shapes(cfg: ModelConfig, shape: Dict, kind: str) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    train/prefill semantics per arch family (DESIGN.md §5/6):
      lm:    tokens [B, S+1]  (loss trains on S positions)
      vlm:   patch embeddings [B, P, feat] + tokens [B, S-P+1] (P+S_text = S)
      audio: frames [B, min(S, enc_cap), feat] + tokens [B, S+1]
    """
    b, s = shape["global_batch"], shape["seq_len"]
    if cfg.arch_type == "vlm":
        pfx = cfg.frontend.n_prefix
        toks = s - pfx
        assert toks > 0
        out = {
            "embeds_prefix": SDS((b, pfx, cfg.frontend.feature_dim), jnp.bfloat16),
            "tokens": SDS((b, toks + 1), jnp.int32),
        }
    elif cfg.arch_type == "audio":
        enc_s = min(s, cfg.encdec.enc_seq_cap)
        out = {
            "frames": SDS((b, enc_s, cfg.frontend.feature_dim), jnp.bfloat16),
            "tokens": SDS((b, s + 1), jnp.int32),
        }
    else:
        out = {"tokens": SDS((b, s + 1), jnp.int32)}
    if kind == "prefill":  # no next-token shift in scoring mode
        out["tokens"] = SDS((out["tokens"].shape[0], out["tokens"].shape[1] - 1),
                            jnp.int32)
    return out


def batch_specs(batch: Dict[str, SDS], mesh: Mesh) -> Dict[str, P]:
    baxes = batch_axes(mesh)
    ax = baxes if len(baxes) > 1 else baxes[0]
    return {k: P(*((ax,) + (None,) * (v.ndim - 1))) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# FLOA config for LLM-scale training
# ---------------------------------------------------------------------------


def default_floa(mesh: Mesh, dim: int, policy: Policy = Policy.BEV,
                 n_byzantine: int = 2, snr_db: float = 10.0,
                 attack: AttackType = AttackType.STRONGEST):
    """The production FLOA setup used by train dry-runs: U = worker-axis size,
    BEV power control (the paper's contribution), N=2 strongest attackers."""
    u = num_workers(mesh)
    n = min(n_byzantine, max(u // 2 - 1, 0))
    return dict(
        channel=ChannelConfig(num_workers=u, sigma=1.0,
                              noise_std=noise_std_for_snr(1.0, dim, snr_db)),
        power=PowerConfig(num_workers=u, dim=dim, p_max=1.0, policy=policy),
        attack=AttackConfig(
            attack=attack if n else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n),
        ),
    )


def init_floa_state():
    return dict(gbar=jnp.zeros((), jnp.float32), eps2=jnp.ones((), jnp.float32))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepArtifacts:
    fn: Callable
    args: Tuple            # ShapeDtypeStruct pytrees (dry-run stand-ins)
    in_shardings: Tuple
    params_specs: Any      # post-FSDP param specs
    meta: Dict


def _with_shard_ctx(fn: Callable, mesh: Mesh) -> Callable:
    """Install the activation-sharding-hint context for the trace of `fn`
    (hints fire at trace time; see models.common.shard_hint)."""
    baxes = batch_axes(mesh)
    mp = model_parallel(mesh)

    def wrapped(*args):
        tok = set_sharding_context(mesh, baxes, mp)
        try:
            return fn(*args)
        finally:
            reset_sharding_context(tok)

    return wrapped


def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    shape: Optional[Dict] = None, *,
                    policy: Policy = Policy.BEV, n_byzantine: int = 2,
                    alpha: float = 1e-3, fsdp: bool = True,
                    use_floa: bool = True) -> StepArtifacts:
    shape = shape or dict(global_batch=256, seq_len=4096)
    u = num_workers(mesh)
    constrain = make_constrain(mesh)
    clogits = make_constrain_logits(mesh)
    key0 = jax.random.PRNGKey(0)
    params_shape, specs = init_model(cfg, key0, shape_only=True)
    dim = param_count(params_shape)
    if fsdp:
        specs = fsdp_augment(specs, params_shape, mesh)
    floa = default_floa(mesh, dim, policy=policy, n_byzantine=n_byzantine)
    channel, power, attack = floa["channel"], floa["power"], floa["attack"]
    moe_coef = cfg.moe.router_aux_coef if cfg.moe else 0.0

    def per_example(params, batch):
        if cfg.arch_type == "audio":
            return ED.encdec_per_example_loss(params, batch, cfg, constrain,
                                              clogits), jnp.zeros((), jnp.float32)
        return T.lm_per_example_loss(params, batch, cfg, constrain=constrain,
                                     constrain_logits=clogits)

    def weighted_loss(params, batch, coeffs):
        per_ex, aux = per_example(params, batch)      # [B], scalar
        per_worker = per_ex.reshape(u, -1).mean(axis=1)  # [U] local losses L_i
        wl = jnp.dot(coeffs, per_worker.astype(jnp.float32))
        if moe_coef:
            wl = wl + moe_coef * aux * jnp.sum(coeffs) / u
        return wl, jnp.mean(per_worker)

    def train_step(params, state, batch, seed):
        key = jax.random.PRNGKey(seed)
        k_ch, k_z = jax.random.split(key)
        if use_floa:
            h_abs = sample_channel_gains(k_ch, channel)
            s, bias_w = ATK.signed_coefficients(
                h_abs, power, channel, attack, state["gbar"], state["eps2"])
        else:
            s = jnp.full((u,), 1.0 / u)
            bias_w = jnp.zeros(())
        (wl, mean_loss), g = jax.value_and_grad(weighted_loss, has_aux=True)(
            params, batch, s)
        # pin gradient shardings to the param layout: scatter-style grads
        # (embedding!) otherwise materialize replicated (40+ GB/device)
        g = jax.tree_util.tree_map(
            lambda sp, gg: jax.lax.with_sharding_constraint(
                gg, NamedSharding(mesh, sp)),
            specs, g, is_leaf=lambda x: isinstance(x, P),
        )

        # de-standardization bias (eq. 7 third term) + receiver AWGN
        eps = jnp.sqrt(state["eps2"])
        leaves, treedef = jax.tree_util.tree_flatten(g)
        noisy = []
        for i, x in enumerate(leaves):
            x = x + (bias_w * state["gbar"]).astype(x.dtype)
            if use_floa and channel.noise_std > 0.0:
                z = jax.random.normal(jax.random.fold_in(k_z, i), x.shape,
                                      jnp.float32)
                x = x + (eps * channel.noise_std * z).astype(x.dtype)
            noisy.append(x)
        g = jax.tree_util.tree_unflatten(treedef, noisy)

        # SGD on the noisy aggregate (eq. 8)
        new_params = jax.tree_util.tree_map(
            lambda p, gg: (p.astype(jnp.float32)
                           - alpha * gg.astype(jnp.float32)).astype(p.dtype),
            params, g)

        # stale-stat estimators for next round (production side channel)
        ssum = jnp.sum(s) + bias_w
        fdim = float(dim)
        s1 = sum(jnp.sum(x.astype(jnp.float32)) for x in leaves)
        s2 = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
        mean_g = s1 / fdim / jnp.where(jnp.abs(ssum) > 1e-9, ssum, 1.0)
        var_g = jnp.maximum(s2 / fdim - (s1 / fdim) ** 2, 1e-20)
        denom = jnp.maximum(jnp.sum(jnp.square(s)), 1e-9)
        new_state = dict(
            gbar=0.9 * state["gbar"] + 0.1 * mean_g,
            eps2=jnp.clip(0.9 * state["eps2"] + 0.1 * var_g / denom,
                          1e-12, 1e12),
        )
        metrics = dict(loss=mean_loss, grad_scale=ssum)
        return new_params, new_state, metrics

    batch = batch_shapes(cfg, shape, "train")
    bspecs = batch_specs(batch, mesh)
    state = jax.tree_util.tree_map(lambda x: SDS(x.shape, x.dtype),
                                   init_floa_state())
    args = (params_shape, state, batch, SDS((), jnp.uint32))
    in_sh = (
        to_shardings(specs, mesh),
        jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), state),
        to_shardings(bspecs, mesh),
        NamedSharding(mesh, P()),
    )
    return StepArtifacts(
        fn=_with_shard_ctx(train_step, mesh), args=args, in_shardings=in_sh,
        params_specs=specs,
        meta=dict(dim=dim, num_workers=u, policy=str(policy)),
    )


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: Dict) -> StepArtifacts:
    constrain = make_constrain(mesh)
    clogits = make_constrain_logits(mesh)
    params_shape, specs = init_model(cfg, jax.random.PRNGKey(0), shape_only=True)
    specs = fsdp_augment(specs, params_shape, mesh)

    def prefill(params, batch):
        # project ONLY the last position's hidden state to logits: the full
        # [B, 32k, vocab] tensor would cost O(100 GB)/device for 163k vocabs
        if cfg.arch_type == "audio":
            enc_out = ED.encode(params, batch["frames"], cfg, constrain)
            h = ED.decode_hidden(params, batch["tokens"], enc_out, cfg,
                                 constrain)
            head = params["lm_head"]
        else:
            h, _ = T.hidden_for_batch(
                params, batch["tokens"], cfg,
                embeds_prefix=batch.get("embeds_prefix"), constrain=constrain)
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
        return clogits(jnp.einsum("bd,dv->bv", h[:, -1, :], head)[:, None])[:, 0]

    batch = batch_shapes(cfg, shape, "prefill")
    bspecs = batch_specs(batch, mesh)
    args = (params_shape, batch)
    in_sh = (to_shardings(specs, mesh), to_shardings(bspecs, mesh))
    return StepArtifacts(fn=_with_shard_ctx(prefill, mesh), args=args,
                         in_shardings=in_sh,
                         params_specs=specs,
                         meta=dict(dim=param_count(params_shape)))


# ---------------------------------------------------------------------------
# decode (serve_step: ONE new token against a seq_len KV cache)
# ---------------------------------------------------------------------------


def decode_window(cfg: ModelConfig, shape_name: str) -> Optional[int]:
    """Effective attention window for a decode shape: the native window if the
    model has one; for long_500k on full-attention dense archs, the explicit
    long-context SWA variant; otherwise full attention."""
    if cfg.window:
        return cfg.window
    if shape_name == "long_500k" and cfg.long_context_window and cfg.mla is None:
        return cfg.long_context_window
    return None


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: Dict,
                     shape_name: str) -> StepArtifacts:
    b, s = shape["global_batch"], shape["seq_len"]
    clogits = make_constrain_logits(mesh)
    params_shape, specs = init_model(cfg, jax.random.PRNGKey(0), shape_only=True)
    specs = fsdp_augment(specs, params_shape, mesh)
    window = decode_window(cfg, shape_name)

    if cfg.arch_type == "audio":
        enc_s = min(s, cfg.encdec.enc_seq_cap)
        caches_shape = jax.eval_shape(lambda: ED.init_dec_caches(cfg, b, s))
        kv, hd = cfg.n_kv_heads, cfg.hd
        cross_shape = (
            SDS((cfg.encdec.n_dec_layers, b, enc_s, kv, hd), cfg.dtype),
            SDS((cfg.encdec.n_dec_layers, b, enc_s, kv, hd), cfg.dtype),
        )
        c_specs = cache_specs({"dec_blocks": caches_shape}, cfg, mesh, b)["dec_blocks"]
        x_specs = cache_specs({"dec_blocks": {"k": cross_shape[0],
                                              "v": cross_shape[1]}}, cfg, mesh, b)
        x_specs = (x_specs["dec_blocks"]["k"], x_specs["dec_blocks"]["v"])

        def step(params, caches, cross_kv, tokens1, pos):
            logits, new_caches = ED.decode_step(params, caches, cross_kv,
                                                tokens1, pos, cfg, clogits)
            return logits, new_caches

        tokens1 = SDS((b, 1), jnp.int32)
        baxes = batch_axes(mesh)
        ax = baxes if len(baxes) > 1 else baxes[0]
        tok_spec = P(ax, None) if b % num_workers(mesh) == 0 else P(None, None)
        args = (params_shape, caches_shape, cross_shape, tokens1,
                SDS((), jnp.int32))
        in_sh = (
            to_shardings(specs, mesh),
            to_shardings(c_specs, mesh),
            to_shardings(x_specs, mesh),
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, P()),
        )
        return StepArtifacts(fn=_with_shard_ctx(step, mesh), args=args,
                             in_shardings=in_sh,
                             params_specs=specs,
                             meta=dict(dim=param_count(params_shape),
                                       window=window))

    caches_shape = jax.eval_shape(
        lambda: T.init_caches(cfg, b, s, window=window))
    c_specs = cache_specs(caches_shape, cfg, mesh, b)

    def step(params, caches, tokens1, pos):
        logits, new_caches = T.decode_step(params, caches, tokens1, pos, cfg,
                                           window=window,
                                           constrain_logits=clogits)
        return logits, new_caches

    tokens1 = SDS((b, 1), jnp.int32)
    baxes = batch_axes(mesh)
    ax = baxes if len(baxes) > 1 else baxes[0]
    tok_spec = P(ax, None) if b % num_workers(mesh) == 0 else P(None, None)
    args = (params_shape, caches_shape, tokens1, SDS((), jnp.int32))
    in_sh = (
        to_shardings(specs, mesh),
        to_shardings(c_specs, mesh),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
    )
    return StepArtifacts(fn=_with_shard_ctx(step, mesh), args=args,
                         in_shardings=in_sh,
                         params_specs=specs,
                         meta=dict(dim=param_count(params_shape), window=window))


def make_step(cfg: ModelConfig, mesh: Mesh, shape_name: str,
              shape: Dict) -> StepArtifacts:
    if shape["kind"] == "train":
        return make_train_step(cfg, mesh, shape)
    if shape["kind"] == "prefill":
        return make_prefill_step(cfg, mesh, shape)
    return make_decode_step(cfg, mesh, shape, shape_name)
