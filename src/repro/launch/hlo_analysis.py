"""Roofline terms from a compiled (SPMD-partitioned) module.

cost_analysis() gives HLO FLOPs/bytes for the per-device partitioned module;
collective bytes are NOT in cost_analysis, so we parse the compiled HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

Hardware constants (TPU v5e, system spec): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.  %ar = bf16[16,256]{1,0} all-reduce(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<dtype>\w+)\[(?P<dims>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result-shape bytes per collective op kind (per device).

    '-start' variants are counted, '-done' skipped (same transfer).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = None
        for op in _COLLECTIVES:
            token = f" {op}("
            start = f" {op}-start("
            if token in stripped or start in stripped:
                m = op
                break
        if m is None or f" {m}-done(" in stripped:
            continue
        # result shape(s) appear between '=' and the op name
        try:
            lhs = stripped.split("=", 1)[1]
            head = lhs.split(m)[0]
        except IndexError:
            continue
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _TUPLE_SHAPE_RE.findall(head)
        )
        out[m] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    """Seconds each resource needs for one step (per chip; cost_analysis is
    reported for the SPMD-partitioned per-device module, so dividing by
    per-chip peaks gives the same answer as global/(chips x peak))."""
    return dict(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=bytes_per_device / HBM_BW,
        collective_s=coll_bytes_per_device / LINK_BW,
    )


def dominant(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])


def model_flops(cfg, shape: Dict, n_params: int, n_active: Optional[int] = None) -> float:
    """MODEL_FLOPS: 6*N*D train tokens (dense; N_active for MoE), 2*N*tokens
    decode, 2*N*D prefill."""
    n = n_active or n_params
    kind = shape["kind"]
    tokens = shape["global_batch"] * (shape["seq_len"] if kind != "decode" else 1)
    per_tok = 6 * n if kind == "train" else 2 * n
    return float(per_tok) * tokens


def active_params(cfg, n_params: int) -> int:
    """Parameters touched per token (MoE: shared + top_k of routed)."""
    if not cfg.moe:
        return n_params
    m = cfg.moe
    expert_p = 3 * cfg.d_model * m.d_expert
    moe_layers = sum(1 for k in cfg.block_pattern if k == "attn_moe")
    frac = moe_layers / len(cfg.block_pattern)
    n_moe_blocks = round(cfg.n_layers * frac)
    routed_total = n_moe_blocks * m.num_experts * expert_p
    routed_active = n_moe_blocks * m.top_k * expert_p
    return n_params - routed_total + routed_active
