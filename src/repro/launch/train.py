"""Training driver: FLOA-federated LM training on a device mesh.

Runs REAL steps (allocating params), so on this CPU host it is meant for
reduced configs; on TPU pods the same entrypoint drives the full configs.

  python -m repro.launch.train --arch qwen3-4b --smoke --mesh 4x2 \
      --steps 20 --batch 8 --seq 64 --policy bev --byzantine 1
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_threefry_partitionable", True)

from repro import checkpoint as CK
from repro.configs import get_config, get_smoke
from repro.core.power_control import Policy
from repro.data import sample_tokens
from repro.launch.distributed import (initialize_distributed,
                                      setup_compilation_cache)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.steps import init_floa_state, init_model, make_train_step


def main() -> None:
    # Multi-host fleets: both are env-driven no-ops on a plain single-process
    # launch (JAX_COORDINATOR_ADDRESS / REPRO_COMPILATION_CACHE unset).
    initialize_distributed()
    setup_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--mesh", default="1x1",
                    help="'RxC' debug mesh, or 'single'/'multi' production")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.02)
    ap.add_argument("--policy", default="bev", choices=["bev", "ci", "ef"])
    ap.add_argument("--byzantine", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    if args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        mp = 16
    else:
        r, c = map(int, args.mesh.split("x"))
        mesh = make_debug_mesh((r, c), ("data", "model"))
        mp = c
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, model_parallel=mp)
    assert cfg.arch_type != "audio" or True

    shape = dict(seq_len=args.seq, global_batch=args.batch, kind="train")
    art = make_train_step(cfg, mesh, shape, alpha=args.alpha,
                          policy=Policy(args.policy),
                          n_byzantine=args.byzantine)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    state = init_floa_state()
    print(f"arch={cfg.name} params={art.meta['dim']:,} workers="
          f"{art.meta['num_workers']} policy={args.policy} "
          f"byzantine={args.byzantine}")

    def make_batch(step: int):
        toks = sample_tokens(args.batch, args.seq + 1,
                             vocab=cfg.vocab_size, seed=step)
        b = {"tokens": jnp.asarray(toks)}
        if cfg.arch_type == "vlm":
            b["embeds_prefix"] = jnp.zeros(
                (args.batch, cfg.frontend.n_prefix, cfg.frontend.feature_dim),
                jnp.float32)
        if cfg.arch_type == "audio":
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(step),
                (args.batch, min(args.seq, cfg.encdec.enc_seq_cap),
                 cfg.frontend.feature_dim))
        return b

    with mesh:
        step_fn = jax.jit(art.fn, in_shardings=art.in_shardings)
        for t in range(args.steps):
            t0 = time.perf_counter()
            params, state, metrics = step_fn(params, state, make_batch(t),
                                             jnp.uint32(t))
            loss = float(metrics["loss"])
            print(f"step {t:4d} loss {loss:8.4f} "
                  f"({time.perf_counter() - t0:5.2f}s)", flush=True)
            assert np.isfinite(loss), "training diverged"
            if args.ckpt and args.ckpt_every and (t + 1) % args.ckpt_every == 0:
                CK.save(args.ckpt, t + 1, jax.device_get(params))
    if args.ckpt:
        CK.save(args.ckpt, args.steps, jax.device_get(params))
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
