"""Sharding utilities: FSDP spec augmentation, cache specs, constraints.

Weight specs come from the ParamFactory (model-axis only).  At production
scale the big MoEs (DeepSeek-V2 236B, Llama-4 400B) do not fit model-axis-
sharded-only (472 GB/16 = 29.5 GB/chip), so `fsdp_augment` additionally
shards the largest free dim of every large leaf over "data" (ZeRO-3); XLA
inserts the per-layer all-gathers (forward) and reduce-scatters (backward)
under the scan.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes, num_workers

FSDP_MIN_SIZE = 1 << 22  # 4M elements: below this, replication is cheaper


def fsdp_augment(specs, params_shapes, mesh: Mesh, axis: str = "data",
                 min_size: int = FSDP_MIN_SIZE):
    """Add `axis` to the largest unsharded dim of big leaves.

    specs/params_shapes are matching pytrees (specs of PartitionSpec, shapes
    of jax.ShapeDtypeStruct or arrays).  Leading scan (layer-stack) dims are
    skipped (dim 0 of stacked leaves) — sharding the scan axis would gather a
    layer per iteration anyway, and the non-leading dims are plenty.
    """
    ax_size = mesh.shape.get(axis, 1)
    if ax_size == 1:
        return specs

    def aug(spec: P, shaped) -> P:
        shape = shaped.shape
        if math.prod(shape) < min_size:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        cand, cand_sz = None, 0
        for i in range(1 if len(shape) > 2 else 0, len(shape)):
            if entries[i] is None and shape[i] % ax_size == 0 and shape[i] > cand_sz:
                cand, cand_sz = i, shape[i]
        if cand is None:
            return spec
        entries[cand] = axis
        return P(*entries)

    return jax.tree_util.tree_map(
        aug, specs, params_shapes, is_leaf=lambda x: isinstance(x, P)
    )


def sweep_state_spec(mesh: Mesh) -> P:
    """PartitionSpec for the sweep engine's flat [S, D(+pad)] state matrix
    (and, prefix-wise, the [S, U, D(+pad)] gradient slab): the scenario-lane
    axis splits over "data", the flat-parameter axis over "model".  The D
    axis is zero-padded once, pre-jit, to a multiple of
    model_shards * TILE_D (`fl.sweep._ModelShards`), so the "model" split is
    always even and every shard's column block is kernel-tile aligned.
    Axes absent from the mesh are simply unmentioned (replicated)."""
    return P("data" if "data" in mesh.axis_names else None,
             "model" if "model" in mesh.axis_names else None)


def to_shardings(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def make_constrain(mesh: Mesh):
    """Activation constraint: [B, S, d] -> batch over worker axes, sequence
    over "model" (sequence-parallel residual streams).  The §Perf experiment
    REPRO_PREFILL_CONSTRAIN=batch_only drops the sequence sharding (trades
    residual memory for the per-layer seq all-gathers)."""
    import os

    baxes = batch_axes(mesh)
    if os.environ.get("REPRO_PREFILL_CONSTRAIN") == "batch_only":
        spec = P(baxes, None, None)
    else:
        spec = P(baxes, "model", None)

    def c(x):
        if x.ndim != 3:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return c


def make_constrain_logits(mesh: Mesh):
    baxes = batch_axes(mesh)
    spec = P(baxes, None, "model")  # vocab-sharded logits

    def c(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return c


def cache_specs(caches_shape, cfg, mesh: Mesh, global_batch: int):
    """PartitionSpecs for a decode-cache pytree (built by eval_shape).

    Structure knowledge: leaves under "blocks"/"enc_blocks"/"dec_blocks" (or
    any stacked tree) carry a leading layer dim -> batch lives at dim 1;
    "tail*" leaves have batch at dim 0.  Model axis goes to the kv-head dim
    when divisible, else to the innermost divisible dim (head_dim / lora dims
    always divide the 16-way mesh).
    """
    mp = mesh.shape.get("model", 1)
    baxes = batch_axes(mesh)
    nworkers = num_workers(mesh)

    def leaf_spec(path, x) -> P:
        keys = [str(getattr(p, "key", "")) for p in path]
        stacked = any(k in ("blocks", "enc_blocks", "dec_blocks") for k in keys)
        bdim = 1 if stacked else 0
        entries = [None] * x.ndim
        if x.shape[bdim] == global_batch and global_batch % nworkers == 0:
            entries[bdim] = baxes if len(baxes) > 1 else baxes[0]
        # model axis: prefer the kv-head dim, else innermost divisible dim
        cand = None
        for i in range(x.ndim - 1, bdim, -1):
            if entries[i] is None and x.shape[i] % mp == 0 and x.shape[i] >= mp:
                cand = i
                if x.shape[i] == cfg.n_kv_heads and x.ndim - i <= 2:
                    break
        if mp > 1 and cand is not None:
            entries[cand] = "model"
        return P(*entries)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(path, x) for path, x in flat]
    )
