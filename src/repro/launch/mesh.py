"""Production mesh construction.

Functions only (importing this module never touches jax device state).
Single pod: 16x16 ("data","model") = 256 chips (TPU v5e pod slice).
Multi-pod:  2x16x16 ("pod","data","model") = 512 chips; the FL worker axis is
("pod","data") = 32 workers, each tensor-parallel over 16 "model" chips.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    assert len(devices) >= n, (
        f"need {n} devices, have {len(devices)} — the dry-run entrypoint must "
        f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
        f"jax import"
    )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_sweep_mesh(num_devices: Optional[int] = None) -> Mesh:
    """1-D ("data",) mesh for sharding a sweep's scenario-lane axis.

    num_devices=None uses every visible device.  On CPU hosts pair with
    XLA_FLAGS=--xla_force_host_platform_device_count=N (set before any jax
    import) to fan the embarrassingly parallel lane axis over N fake devices.
    """
    devices = jax.devices()
    n = len(devices) if num_devices is None else num_devices
    assert len(devices) >= n, f"need {n} devices, have {len(devices)}"
    return Mesh(np.asarray(devices[:n]), ("data",))


def make_debug_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    n = math.prod(shape)
    devices = jax.devices()
    assert len(devices) >= n, f"need {n} devices, have {len(devices)}"
    return Mesh(np.asarray(devices[:n]).reshape(tuple(shape)), tuple(axes))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The FL-worker / batch axes of a mesh (everything except "model")."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_workers(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


def model_parallel(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
