"""Production mesh construction and sweep staging placement.

Functions only (importing this module never touches jax device state).
Single pod: 16x16 ("data","model") = 256 chips (TPU v5e pod slice).
Multi-pod:  2x16x16 ("pod","data","model") = 512 chips; the FL worker axis is
("pod","data") = 32 workers, each tensor-parallel over 16 "model" chips.

The sweep-engine placement helpers (`lane_sharding` / `replicated_sharding` /
`stage_batch_block`) centralize how sweep operands land on a sweep mesh —
1-D ("data",), 1-D ("workers",), or 2-D ("data", "workers"), built by
`make_sweep_mesh`: lane-stacked operands (state, keys, ScenarioParams) split
on the lane axis over "data" (replicated over "workers"), batch blocks
replicate.  `stage_batch_block` is the host->device edge
of the chunked engine's double-buffered input pipeline — `jax.device_put` is
asynchronous, so a block staged while the previous chunk computes lands
pre-sharded with no device idle time and no resharding inside the
shard_mapped scan.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    assert len(devices) >= n, (
        f"need {n} devices, have {len(devices)} — the dry-run entrypoint must "
        f"set XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
        f"jax import"
    )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_sweep_mesh(num_devices: Optional[int] = None,
                    worker_shards: int = 1,
                    model_shards: int = 1) -> Mesh:
    """Sweep mesh: 1-D ("data",) over the scenario-lane axis by default;
    worker_shards=W > 1 adds a ("workers",) axis that the [S, U, D]
    gradient slab's worker axis shards over (the OTA combine becomes a psum
    over worker shards — see fl/sweep.py); model_shards=M > 1 adds a
    ("model",) axis that the flat [S, D] state's (and slab's) D axis shards
    over — D is padded to a multiple of M * TILE_D pre-jit and the OTA
    combine / stats / column-wise screening run shard-local over D.

    Shapes: with worker_shards=1 and model_shards=1 the mesh is the 1-D
    ("data",) lane mesh (every prior caller unchanged).  Otherwise the
    device count factors as data x W x M with the axes always ordered
    ("data", "workers", "model") and size-1 axes dropped — e.g. (8, W=4)
    is the 2x4 ("data", "workers") mesh, (8, M=8) the 1-D ("model",) mesh,
    and (8, W=2, M=2) the 2x2x2 ("data", "workers", "model") mesh.

    num_devices=None uses every visible device.  On CPU hosts pair with
    XLA_FLAGS=--xla_force_host_platform_device_count=N (set before any jax
    import) to fan the embarrassingly parallel lane axis over N fake devices.
    """
    devices = jax.devices()
    n = len(devices) if num_devices is None else num_devices
    assert len(devices) >= n, f"need {n} devices, have {len(devices)}"
    assert worker_shards >= 1, worker_shards
    assert model_shards >= 1, model_shards
    if worker_shards == 1 and model_shards == 1:
        return Mesh(np.asarray(devices[:n]), ("data",))
    assert n % (worker_shards * model_shards) == 0, (
        f"num_devices={n} not divisible by worker_shards={worker_shards} * "
        f"model_shards={model_shards}")
    dims = (("data", n // (worker_shards * model_shards)),
            ("workers", worker_shards), ("model", model_shards))
    kept = tuple((a, s) for a, s in dims if s > 1)
    shape = tuple(s for _, s in kept)
    axes = tuple(a for a, _ in kept)
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    n = math.prod(shape)
    devices = jax.devices()
    assert len(devices) >= n, f"need {n} devices, have {len(devices)}"
    return Mesh(np.asarray(devices[:n]).reshape(tuple(shape)), tuple(axes))


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for lane-stacked sweep operands: axis 0 splits over "data"
    (replicated over any "workers" axis; a 1-D ("workers",) mesh has no lane
    axis to split, so everything lands replicated)."""
    spec = (PartitionSpec("data") if "data" in mesh.axis_names
            else PartitionSpec())
    return NamedSharding(mesh, spec)


def sweep_state_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for the flat [S, D(+pad)] sweep state: lane axis over "data",
    flat-parameter axis over "model" (see `launch.sharding.sweep_state_spec`
    for the padding contract)."""
    # Lazy import: launch.sharding imports from this module at top level.
    from repro.launch.sharding import sweep_state_spec
    return NamedSharding(mesh, sweep_state_spec(mesh))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding for per-round batch blocks: replicated on every device (each
    lane shard consumes the same batch stream)."""
    return NamedSharding(mesh, PartitionSpec())


def put_with_sharding(x, sharding: NamedSharding):
    """Place one host-side array under `sharding`, multi-process aware.

    A fully-addressable sharding (every mesh device owned by this process
    — the single-process case) is a plain async `jax.device_put`,
    unchanged from the pre-distributed engine.  A process-spanning mesh
    takes the `jax.make_array_from_callback` route instead: every process
    holds the same full host-side value and materializes ONLY its own
    addressable shards from it — this is the per-process feeding edge of a
    multi-host sweep (for replicated operands each process uploads the
    whole value once; for lane-sharded operands each uploads just its
    lanes).
    """
    if sharding.is_fully_addressable:
        return jax.device_put(x, sharding)
    arr = np.asarray(x)
    return jax.make_array_from_callback(arr.shape, sharding,
                                        lambda idx: arr[idx])


def stage_batch_block(block, mesh: Optional[Mesh] = None):
    """Transfer one host-side batch block (pytree of [C, ...] arrays) to the
    device(s), asynchronously.

    With a ("data",) sweep mesh the block lands pre-sharded (replicated over
    the mesh) so the shard_mapped scan consumes it with zero resharding;
    without a mesh it is a plain async `jax.device_put` to the default
    device.  Either way the call returns immediately — the transfer overlaps
    whatever the device is executing, which is what makes the chunked
    engine's `async_staging` double buffer work.  On a process-spanning
    mesh (see `launch.distributed.initialize_distributed`) each process
    stages its own addressable replicas via `put_with_sharding`.
    """
    if mesh is None:
        return jax.tree_util.tree_map(jax.device_put, block)
    sharding = replicated_sharding(mesh)
    return jax.tree_util.tree_map(
        lambda x: put_with_sharding(x, sharding), block)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The FL-worker / batch axes of a mesh (everything except "model")."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_workers(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in batch_axes(mesh))


def model_parallel(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
