"""Multi-host bootstrap + persistent compilation cache for sweep fleets.

`initialize_distributed` wraps `jax.distributed.initialize` so a sweep
script becomes multi-process by adding three arguments (or the matching
environment variables) and nothing else:

    initialize_distributed(coordinator_address="10.0.0.1:1234",
                           num_processes=4, process_id=rank)
    mesh = make_sweep_mesh()          # jax.devices() is now GLOBAL:
                                      # the mesh spans every process
    plan = ExecutionPlan(mesh=mesh, chunk_rounds=32)

After initialization `jax.devices()` enumerates every process's devices,
so the existing `make_sweep_mesh` builds a process-spanning mesh with no
new code path — each process then feeds the full host-side batch stream
into `stage_batch_block`, which materializes only that process's
addressable shards (see `launch.mesh.put_with_sharding`).  Called with no
arguments in a single-process job it is a no-op, keeping the
single-process sweep bitwise-identical to the pre-distributed engine.

On CPU backends the default collectives implementation cannot cross
processes ("Multiprocess computations aren't implemented on the CPU
backend"); we switch it to gloo BEFORE initialize, which is what makes
the 2-process CI smoke real.

`setup_compilation_cache` points `jax.experimental.compilation_cache` at
a persistent directory (argument, else $REPRO_COMPILATION_CACHE) so a
restarted/resumed fleet skips recompiles — the other half of
preemption-safe sweeps next to the engine's chunk-boundary checkpoints.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

import jax

#: Environment variable naming the persistent compilation-cache directory.
CACHE_ENV = "REPRO_COMPILATION_CACHE"


def _already_initialized() -> bool:
    """Whether the jax distributed runtime is already up.  Prefers the
    public `jax.distributed.is_initialized` (jax >= 0.4.34); falls back to
    the internal global_state on older versions, and to False when neither
    is readable — `initialize` itself then raises if called twice, which
    beats an ImportError at module import time."""
    is_init = getattr(jax.distributed, "is_initialized", None)
    if is_init is not None:
        try:
            return bool(is_init())
        except Exception:
            pass
    try:
        from jax._src.distributed import global_state
        return global_state.coordinator_address is not None
    except Exception:
        return False


def initialize_distributed(coordinator_address: Optional[str] = None,
                           num_processes: Optional[int] = None,
                           process_id: Optional[int] = None,
                           local_device_ids=None) -> bool:
    """Bootstrap the JAX distributed runtime (idempotent, single-process
    no-op).

    Returns True when a multi-process runtime was (or already is) up,
    False for the single-process no-op.  Arguments default to None so the
    standard cluster-environment variables (JAX_COORDINATOR_ADDRESS /
    JAX_NUM_PROCESSES / JAX_PROCESS_ID, or an auto-detected cluster) can
    fill them in, exactly as `jax.distributed.initialize` documents.

    Explicit num_processes=1 (or an environment resolving to one process)
    skips initialization entirely: single-process stays on the default
    runtime and remains bitwise-identical to a never-distributed run.

    Nothing here touches the XLA backends before `initialize` runs —
    jax refuses to bootstrap after any computation has executed, and even
    `jax.process_count()` would count as one.
    """
    if _already_initialized():
        return jax.process_count() > 1
    if num_processes == 1:
        return False
    if (coordinator_address is None and num_processes is None
            and process_id is None
            and "JAX_COORDINATOR_ADDRESS" not in os.environ):
        return False                      # single-process job, nothing to do
    # The default CPU collectives cannot cross processes; gloo can.  Must
    # be set before initialize; a no-op for non-CPU backends (and the
    # config may not exist on every jax version — then CPU multi-process
    # is unsupported anyway).
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id,
                               local_device_ids=local_device_ids)
    return jax.process_count() > 1


def setup_compilation_cache(cache_dir: Optional[str] = None,
                            min_compile_time_secs: Optional[float] = None
                            ) -> Optional[str]:
    """Enable the persistent XLA compilation cache.

    cache_dir=None reads $REPRO_COMPILATION_CACHE; when that is unset too,
    this is a no-op returning None (so entry points can call it
    unconditionally).  min_compile_time_secs lowers jax's "don't bother
    caching fast compiles" threshold — pass 0 to cache everything, which
    the warm-restart benchmark needs for its deliberately tiny programs.
    Returns the cache directory in use.
    """
    cache_dir = cache_dir if cache_dir is not None else os.environ.get(
        CACHE_ENV)
    if not cache_dir:
        return None
    from jax.experimental import compilation_cache as cc
    cc.compilation_cache.set_cache_dir(cache_dir)
    if min_compile_time_secs is not None:
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          float(min_compile_time_secs))
    return cache_dir


def fetch(x):
    """Host numpy copy of `x`, whether it is process-local or a global
    array sharded across processes (the result fetch edge of a
    multi-process sweep: loss/metric trajectories and final params come
    back fully replicated on every process)."""
    if isinstance(x, jax.Array) and not x.is_fully_addressable:
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(x, tiled=True))
    return np.asarray(x)
