"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def floa_aggregate_ref(coeffs: Array, grads: Array, noise: Array,
                       bias: Array, eps: Array) -> Array:
    """out[d] = sum_u coeffs[u] grads[u,d] + bias + eps * noise[d].

    coeffs [U] f32, grads [U, D], noise [D], bias/eps scalars.  f32 accumulate.
    """
    acc = jnp.einsum("u,ud->d", coeffs.astype(jnp.float32),
                     grads.astype(jnp.float32))
    return (acc + bias + eps * noise.astype(jnp.float32)).astype(grads.dtype)


def floa_aggregate_batched_ref(coeffs: Array, grads: Array, noise: Array,
                               bias: Array, eps: Array) -> Array:
    """out[s,d] = sum_u coeffs[s,u] grads[s,u,d] + bias[s] + eps[s] noise[s,d].

    coeffs [S, U] f32, grads [S, U, D], noise [S, D], bias/eps [S].
    """
    acc = jnp.einsum("su,sud->sd", coeffs.astype(jnp.float32),
                     grads.astype(jnp.float32))
    out = acc + bias[:, None] + eps[:, None] * noise.astype(jnp.float32)
    return out.astype(grads.dtype)


def floa_step_batched_ref(w: Array, coeffs: Array, grads: Array, noise: Array,
                          bias: Array, eps: Array, alpha: Array):
    """Fused combine + PS update for a scenario sweep.

    gagg[s,d]  = sum_u coeffs[s,u] grads[s,u,d] + bias[s] + eps[s] noise[s,d]
    w_new[s,d] = w[s,d] - alpha[s] * gagg[s,d]

    w [S, D], coeffs [S, U], grads [S, U, D], noise [S, D], bias/eps/alpha [S].
    Returns (w_new, gagg) — gagg is materialized so callers can log grad
    norms without a second pass.  f32 accumulate, like the combine oracle.
    """
    gagg = floa_aggregate_batched_ref(coeffs, grads, noise, bias, eps)
    w_new = (w.astype(jnp.float32)
             - alpha[:, None].astype(jnp.float32) * gagg.astype(jnp.float32))
    return w_new.astype(w.dtype), gagg


def sort_columns_ref(x: Array) -> Array:
    """[U, D] -> [U, D] ascending along the worker axis (axis 0) — the
    oracle for the odd-even transposition-network kernel (finite inputs;
    the network's min/max compare-exchanges do not reproduce sort's
    NaNs-last ordering)."""
    return jnp.sort(x, axis=0)


def sort_columns_batched_ref(x: Array) -> Array:
    """[S, U, D] -> [S, U, D] ascending along the worker axis (axis 1)."""
    return jnp.sort(x, axis=1)


def grad_stats_ref(grads: Array) -> Array:
    """Per-worker [U, 2] f32: (sum_d g, sum_d g^2) — the eq. (3) stats."""
    g = grads.astype(jnp.float32)
    return jnp.stack([jnp.sum(g, axis=1), jnp.sum(g * g, axis=1)], axis=1)


def decode_attention_ref(q: Array, k: Array, v: Array, pos: Array) -> Array:
    """GQA decode: one query token vs a KV cache.

    q [B,H,dh]; k/v [B,S,KV,dh]; pos scalar int (positions > pos are masked).
    Returns [B,H,dh].  Softmax in f32.
    """
    b, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    valid = jnp.arange(s) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(v.dtype), v)
    return out.reshape(b, h, dh)
