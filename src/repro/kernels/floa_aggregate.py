"""Fused FLOA aggregation kernels (the paper's hot spot, eq. 7-8).

`floa_aggregate` computes out[d] = sum_u s[u] * G[u, d] + bias + eps * z[d]
in one pass over the gradient: per-worker scale, over-the-air superposition,
de-standardization bias, and receiver-noise injection are fused so the [U, D]
gradient block is read exactly once from HBM (the op is bandwidth-bound:
U*D reads, D writes, 2*U*D flops -> arithmetic intensity ~1 flop/byte, so
fusion is the whole win).

`floa_step_batched` additionally fuses the PS update (eq. 8) into the same
pass: w_new[s] = w[s] - alpha[s] * (coeffs[s] @ G[s] + bias[s] + eps[s] z[s]).
The aggregate is emitted as a second output so callers can log grad norms;
writes grow from D to 2*D per scenario but the U*D gradient reads still
dominate, and the parameter row is read/written exactly once.

Tiling: grid over D in TILE_D (=2048, a multiple of the 128-lane VPU width)
steps; the [U, TILE_D] slab plus coefficient vector live in VMEM.  For
U<=32, TILE_D=2048, bf16: 32*2048*2 = 128 KiB slab — comfortably inside the
~16 MiB VMEM budget with double-buffering.

D-padding happens once, in the un-jitted public wrappers, before the jitted
pallas_call core is entered (an earlier version recursed back into the jitted
entry point with re-padded operands, re-entering the jit trace; see the
non-multiple-of-TILE_D regression tests in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TILE_D = 2048


def _pad_last(x: Array, pad: int) -> Array:
    """Zero-pad the last axis by `pad` entries (no-op when pad == 0)."""
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _kernel(scal_ref, coeff_ref, g_ref, z_ref, o_ref):
    s = coeff_ref[:].astype(jnp.float32)            # [U]
    g = g_ref[:].astype(jnp.float32)                # [U, TILE_D]
    z = z_ref[:].astype(jnp.float32)                # [TILE_D]
    bias = scal_ref[0, 0]
    eps = scal_ref[0, 1]
    acc = jnp.sum(s[:, None] * g, axis=0)           # VPU reduce over workers
    o_ref[:] = (acc + bias + eps * z).astype(o_ref.dtype)


def _batched_kernel(scal_ref, coeff_ref, g_ref, z_ref, o_ref):
    s = coeff_ref[:].astype(jnp.float32)            # [1, U] scenario row
    g = g_ref[:].astype(jnp.float32)                # [1, U, TILE_D]
    z = z_ref[:].astype(jnp.float32)                # [1, TILE_D]
    bias = scal_ref[0, 0]
    eps = scal_ref[0, 1]
    acc = jnp.sum(s[0, :, None] * g[0], axis=0)     # VPU reduce over workers
    o_ref[:] = (acc + bias + eps * z[0])[None].astype(o_ref.dtype)


def _batched_step_kernel(scal_ref, coeff_ref, w_ref, g_ref, z_ref,
                         wo_ref, go_ref):
    s = coeff_ref[:].astype(jnp.float32)            # [1, U] scenario row
    w = w_ref[:].astype(jnp.float32)                # [1, TILE_D] params
    g = g_ref[:].astype(jnp.float32)                # [1, U, TILE_D]
    z = z_ref[:].astype(jnp.float32)                # [1, TILE_D]
    bias = scal_ref[0, 0]
    eps = scal_ref[0, 1]
    alpha = scal_ref[0, 2]
    gagg = jnp.sum(s[0, :, None] * g[0], axis=0) + bias + eps * z[0]
    go_ref[:] = gagg[None].astype(go_ref.dtype)
    wo_ref[:] = (w[0] - alpha * gagg)[None].astype(wo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def _floa_aggregate_batched_core(coeffs: Array, grads: Array, noise: Array,
                                 bias: Array, eps: Array, interpret: bool,
                                 tile_d: int) -> Array:
    s_n, u, d = grads.shape
    assert d % tile_d == 0, "core requires pre-padded D (see public wrapper)"
    scal = jnp.stack([bias.astype(jnp.float32),
                      eps.astype(jnp.float32)], axis=1)  # [S, 2]
    return pl.pallas_call(
        _batched_kernel,
        grid=(s_n, d // tile_d),
        in_specs=[
            pl.BlockSpec((1, 2), lambda s, i: (s, 0)),          # scalar row
            pl.BlockSpec((1, u), lambda s, i: (s, 0)),          # coeff row
            pl.BlockSpec((1, u, tile_d), lambda s, i: (s, 0, i)),  # grad slab
            pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),     # noise row
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),
        out_shape=jax.ShapeDtypeStruct((s_n, d), grads.dtype),
        interpret=interpret,
    )(scal, coeffs.astype(jnp.float32), grads, noise)


def floa_aggregate_batched(coeffs: Array, grads: Array, noise: Array,
                           bias: Array, eps: Array, interpret: bool = False,
                           tile_d: int = TILE_D) -> Array:
    """Batched scenario-sweep variant of `floa_aggregate`.

    coeffs [S, U] f32, grads [S, U, D], noise [S, D], bias/eps [S] -> [S, D].
    Grid is (S, D // TILE_D): scenario-major so each scenario's coeff/bias/eps
    row is loaded once and reused across its D tiles; the [U, TILE_D] gradient
    slab per grid step is identical to the unbatched kernel, so the VMEM
    budget does not grow with S.
    """
    s_n, u, d = grads.shape
    assert coeffs.shape == (s_n, u) and noise.shape == (s_n, d)
    assert bias.shape == (s_n,) and eps.shape == (s_n,)
    pad = -d % tile_d  # single pad before the jitted core (D is huge anyway)
    out = _floa_aggregate_batched_core(
        coeffs, _pad_last(grads, pad), _pad_last(noise, pad), bias, eps,
        interpret=interpret, tile_d=tile_d)
    return out[:, :d] if pad else out


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def _floa_step_batched_core(w: Array, coeffs: Array, grads: Array,
                            noise: Array, bias: Array, eps: Array,
                            alpha: Array, interpret: bool, tile_d: int):
    s_n, u, d = grads.shape
    assert d % tile_d == 0, "core requires pre-padded D (see public wrapper)"
    scal = jnp.stack([bias.astype(jnp.float32),
                      eps.astype(jnp.float32),
                      alpha.astype(jnp.float32)], axis=1)  # [S, 3]
    return pl.pallas_call(
        _batched_step_kernel,
        grid=(s_n, d // tile_d),
        in_specs=[
            pl.BlockSpec((1, 3), lambda s, i: (s, 0)),          # scalar row
            pl.BlockSpec((1, u), lambda s, i: (s, 0)),          # coeff row
            pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),     # param row
            pl.BlockSpec((1, u, tile_d), lambda s, i: (s, 0, i)),  # grad slab
            pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),     # noise row
        ],
        out_specs=[
            pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),     # new params
            pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),     # aggregate
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_n, d), w.dtype),
            jax.ShapeDtypeStruct((s_n, d), grads.dtype),
        ],
        interpret=interpret,
    )(scal, coeffs.astype(jnp.float32), w, grads, noise)


def floa_step_batched(w: Array, coeffs: Array, grads: Array, noise: Array,
                      bias: Array, eps: Array, alpha: Array,
                      interpret: bool = False, tile_d: int = TILE_D):
    """Fused combine + PS update over the [S, U, D] slab (eq. 7 + eq. 8).

    w [S, D], coeffs [S, U] f32, grads [S, U, D], noise [S, D],
    bias/eps/alpha [S] -> (w_new [S, D], gagg [S, D]).

    Same grid/VMEM layout as `floa_aggregate_batched` plus one parameter row
    in and two rows out per tile; the parameter state never leaves flat [S, D]
    form, which is what makes the sweep engine's flat-state scan one pass.
    """
    s_n, u, d = grads.shape
    assert w.shape == (s_n, d) and coeffs.shape == (s_n, u)
    assert noise.shape == (s_n, d)
    assert bias.shape == (s_n,) and eps.shape == (s_n,)
    assert alpha.shape == (s_n,)
    pad = -d % tile_d  # single pad before the jitted core
    w_new, gagg = _floa_step_batched_core(
        _pad_last(w, pad), coeffs, _pad_last(grads, pad),
        _pad_last(noise, pad), bias, eps, alpha,
        interpret=interpret, tile_d=tile_d)
    if pad:
        w_new, gagg = w_new[:, :d], gagg[:, :d]
    return w_new, gagg


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def _floa_aggregate_core(coeffs: Array, grads: Array, noise: Array,
                         bias: Array, eps: Array, interpret: bool,
                         tile_d: int) -> Array:
    u, d = grads.shape
    assert d % tile_d == 0, "core requires pre-padded D (see public wrapper)"
    scal = jnp.stack([bias.astype(jnp.float32),
                      eps.astype(jnp.float32)]).reshape(1, 2)
    return pl.pallas_call(
        _kernel,
        grid=(d // tile_d,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),            # scalars
            pl.BlockSpec((u,), lambda i: (0,)),                # coeffs
            pl.BlockSpec((u, tile_d), lambda i: (0, i)),       # gradient slab
            pl.BlockSpec((tile_d,), lambda i: (i,)),           # noise
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), grads.dtype),
        interpret=interpret,
    )(scal, coeffs, grads, noise)


def floa_aggregate(coeffs: Array, grads: Array, noise: Array, bias: Array,
                   eps: Array, interpret: bool = False,
                   tile_d: int = TILE_D) -> Array:
    """coeffs [U] f32, grads [U, D], noise [D], bias/eps scalars -> [D]."""
    u, d = grads.shape
    pad = -d % tile_d  # single pad before the jitted core
    out = _floa_aggregate_core(
        coeffs, _pad_last(grads, pad), _pad_last(noise, pad), bias, eps,
        interpret=interpret, tile_d=tile_d)
    return out[:d] if pad else out
