"""Fused FLOA aggregation kernel (the paper's hot spot, eq. 7).

Computes out[d] = sum_u s[u] * G[u, d] + bias + eps * z[d] in one pass over
the gradient: per-worker scale, over-the-air superposition, de-standardization
bias, and receiver-noise injection are fused so the [U, D] gradient block is
read exactly once from HBM (the op is bandwidth-bound: U*D reads, D writes,
2*U*D flops -> arithmetic intensity ~1 flop/byte, so fusion is the whole win).

Tiling: grid over D in TILE_D (=2048, a multiple of the 128-lane VPU width)
steps; the [U, TILE_D] slab plus coefficient vector live in VMEM.  For
U<=32, TILE_D=2048, bf16: 32*2048*2 = 128 KiB slab — comfortably inside the
~16 MiB VMEM budget with double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TILE_D = 2048


def _kernel(scal_ref, coeff_ref, g_ref, z_ref, o_ref):
    s = coeff_ref[:].astype(jnp.float32)            # [U]
    g = g_ref[:].astype(jnp.float32)                # [U, TILE_D]
    z = z_ref[:].astype(jnp.float32)                # [TILE_D]
    bias = scal_ref[0, 0]
    eps = scal_ref[0, 1]
    acc = jnp.sum(s[:, None] * g, axis=0)           # VPU reduce over workers
    o_ref[:] = (acc + bias + eps * z).astype(o_ref.dtype)


def _batched_kernel(scal_ref, coeff_ref, g_ref, z_ref, o_ref):
    s = coeff_ref[:].astype(jnp.float32)            # [1, U] scenario row
    g = g_ref[:].astype(jnp.float32)                # [1, U, TILE_D]
    z = z_ref[:].astype(jnp.float32)                # [1, TILE_D]
    bias = scal_ref[0, 0]
    eps = scal_ref[0, 1]
    acc = jnp.sum(s[0, :, None] * g[0], axis=0)     # VPU reduce over workers
    o_ref[:] = (acc + bias + eps * z[0])[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def floa_aggregate_batched(coeffs: Array, grads: Array, noise: Array,
                           bias: Array, eps: Array, interpret: bool = False,
                           tile_d: int = TILE_D) -> Array:
    """Batched scenario-sweep variant of `floa_aggregate`.

    coeffs [S, U] f32, grads [S, U, D], noise [S, D], bias/eps [S] -> [S, D].
    Grid is (S, D // TILE_D): scenario-major so each scenario's coeff/bias/eps
    row is loaded once and reused across its D tiles; the [U, TILE_D] gradient
    slab per grid step is identical to the unbatched kernel, so the VMEM
    budget does not grow with S.
    """
    s_n, u, d = grads.shape
    assert coeffs.shape == (s_n, u) and noise.shape == (s_n, d)
    assert bias.shape == (s_n,) and eps.shape == (s_n,)
    if d % tile_d:  # pad D to a tile multiple (cheap; D is huge in practice)
        pad = tile_d - d % tile_d
        grads = jnp.pad(grads, ((0, 0), (0, 0), (0, pad)))
        noise = jnp.pad(noise, ((0, 0), (0, pad)))
        return floa_aggregate_batched(coeffs, grads, noise, bias, eps,
                                      interpret=interpret,
                                      tile_d=tile_d)[:, :d]
    scal = jnp.stack([bias.astype(jnp.float32),
                      eps.astype(jnp.float32)], axis=1)  # [S, 2]
    return pl.pallas_call(
        _batched_kernel,
        grid=(s_n, d // tile_d),
        in_specs=[
            pl.BlockSpec((1, 2), lambda s, i: (s, 0)),          # scalar row
            pl.BlockSpec((1, u), lambda s, i: (s, 0)),          # coeff row
            pl.BlockSpec((1, u, tile_d), lambda s, i: (s, 0, i)),  # grad slab
            pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),     # noise row
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda s, i: (s, i)),
        out_shape=jax.ShapeDtypeStruct((s_n, d), grads.dtype),
        interpret=interpret,
    )(scal, coeffs.astype(jnp.float32), grads, noise)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def floa_aggregate(coeffs: Array, grads: Array, noise: Array, bias: Array,
                   eps: Array, interpret: bool = False,
                   tile_d: int = TILE_D) -> Array:
    """coeffs [U] f32, grads [U, D], noise [D], bias/eps scalars -> [D]."""
    u, d = grads.shape
    if d % tile_d:  # pad D to a tile multiple (cheap; D is huge in practice)
        pad = tile_d - d % tile_d
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
        noise = jnp.pad(noise, (0, pad))
        return floa_aggregate(coeffs, grads, noise, bias, eps,
                              interpret=interpret, tile_d=tile_d)[:d]
    scal = jnp.stack([bias.astype(jnp.float32),
                      eps.astype(jnp.float32)]).reshape(1, 2)
    return pl.pallas_call(
        _kernel,
        grid=(d // tile_d,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),            # scalars
            pl.BlockSpec((u,), lambda i: (0,)),                # coeffs
            pl.BlockSpec((u, tile_d), lambda i: (0, i)),       # gradient slab
            pl.BlockSpec((tile_d,), lambda i: (i,)),           # noise
        ],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), grads.dtype),
        interpret=interpret,
    )(scal, coeffs, grads, noise)
