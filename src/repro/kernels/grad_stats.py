"""Per-worker gradient statistics kernel (standardization round, eq. 3).

Computes, for each worker u, (sum_d G[u,d], sum_d G[u,d]^2) in one HBM pass
with f32 accumulators.  The mean/variance the workers report to the PS follow
as gbar = s1/D, eps2 = s2/D - gbar^2 on scalars.

Tiling: grid over D; the [U, 2] accumulator block is revisited by every grid
step (output index_map constant), a standard Pallas reduction: initialized at
step 0, accumulated thereafter.  On real TPUs the (U, 2) output pads to the
(8, 128) tile — negligible next to the [U, D] stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TILE_D = 2048


def _kernel(g_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[:] = jnp.zeros_like(o_ref)

    g = g_ref[:].astype(jnp.float32)                # [U, TILE_D]
    s1 = jnp.sum(g, axis=1)
    s2 = jnp.sum(g * g, axis=1)
    o_ref[:] = o_ref[:] + jnp.stack([s1, s2], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def grad_stats(grads: Array, interpret: bool = False,
               tile_d: int = TILE_D) -> Array:
    """grads [U, D] -> [U, 2] f32 (sum, sum of squares)."""
    u, d = grads.shape
    if d % tile_d:
        grads = jnp.pad(grads, ((0, 0), (0, tile_d - d % tile_d)))
        d = grads.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(d // tile_d,),
        in_specs=[pl.BlockSpec((u, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((u, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((u, 2), jnp.float32),
        interpret=interpret,
    )(grads)
