"""Public jit'd entry points for the Pallas kernels.

On CPU hosts (this container) `interpret=True` executes the kernel bodies in
Python for correctness validation; on TPU the same calls compile to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_attention
from repro.kernels.floa_aggregate import floa_aggregate as _floa_aggregate
from repro.kernels.floa_aggregate import (
    floa_aggregate_batched as _floa_aggregate_batched,
)
from repro.kernels.floa_aggregate import floa_step_batched as _floa_step_batched
from repro.kernels.defense_sort import (
    BITONIC_MAX_U,
    UNROLL_MAX_U,
    sort_columns as _sort_columns,
    sort_columns_bitonic as _sort_columns_bitonic,
)
from repro.kernels.grad_stats import grad_stats as _grad_stats

Array = jax.Array


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def floa_aggregate(coeffs, grads, noise, bias, eps, interpret=None) -> Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _floa_aggregate(coeffs, grads, noise, jnp.asarray(bias),
                           jnp.asarray(eps), interpret=interpret)


def floa_aggregate_batched(coeffs, grads, noise, bias, eps,
                           interpret=None) -> Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _floa_aggregate_batched(coeffs, grads, noise, jnp.asarray(bias),
                                   jnp.asarray(eps), interpret=interpret)


def floa_step_batched(w, coeffs, grads, noise, bias, eps, alpha,
                      interpret=None):
    """Fused [S, U, D] combine + PS update; returns (w_new, gagg)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _floa_step_batched(w, coeffs, grads, noise, jnp.asarray(bias),
                              jnp.asarray(eps), jnp.asarray(alpha),
                              interpret=interpret)


def sort_columns(x, interpret=None) -> Array:
    """[U, D] ascending sort along the worker axis (odd-even network,
    U <= UNROLL_MAX_U).  Batched use goes through `jax.vmap` (Pallas lifts
    it into a leading grid dimension); `sort_columns_batched_ref` is that
    route's oracle."""
    interpret = _interpret_default() if interpret is None else interpret
    return _sort_columns(x, interpret=interpret)


def sort_columns_bitonic(x, interpret=None) -> Array:
    """[U, D] ascending sort along the worker axis — the large-U successor
    to `sort_columns`: O(log^2 U) bitonic stages instead of an O(U^2)
    unrolled network, U padded to a power of two (<= BITONIC_MAX_U).  Same
    oracle (`sort_columns_ref`) and vmap route as `sort_columns`."""
    interpret = _interpret_default() if interpret is None else interpret
    return _sort_columns_bitonic(x, interpret=interpret)


def grad_stats(grads, interpret=None) -> Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _grad_stats(grads, interpret=interpret)


def decode_attention(q, k, v, pos, interpret=None) -> Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _decode_attention(q, k, v, pos, interpret=interpret)


# oracles re-exported for tests/benchmarks
floa_aggregate_ref = ref.floa_aggregate_ref
floa_aggregate_batched_ref = ref.floa_aggregate_batched_ref
floa_step_batched_ref = ref.floa_step_batched_ref
sort_columns_ref = ref.sort_columns_ref
sort_columns_batched_ref = ref.sort_columns_batched_ref
grad_stats_ref = ref.grad_stats_ref
decode_attention_ref = ref.decode_attention_ref
