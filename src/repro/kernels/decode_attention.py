"""GQA decode-attention kernel (flash-decoding): one query token, long KV.

The serve-shape hot spot (decode_32k / long_500k): attention of a single new
token against an S-long KV cache is pure memory streaming (read K+V once,
~4 flops/byte), so the kernel's job is to keep the stream dense and the
softmax online so no [S]-sized score tensor ever hits HBM.

Tiling: grid (B, S/TILE_S).  Per batch row the KV stream is swept in TILE_S
(=512) slabs; running (m, l, acc) online-softmax state lives in VMEM scratch
and persists across the S-sweep (TPU grid is sequential-minor, so the state
is private to each batch row).  VMEM per step: 2 * TILE_S * KV * dh bf16
(e.g. 512*8*128*2*2 = 2 MiB for KV=8, dh=128) + O(H*dh) state.

The mask `kpos <= pos` makes the same kernel serve both dense caches and the
ring-buffer windows (callers pass per-slot positions via `kpos`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

TILE_S = 512


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    si = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(si == 0)
    def _init():
        m_ref[:] = jnp.full_like(m_ref, -1e30)
        l_ref[:] = jnp.zeros_like(l_ref)
        acc_ref[:] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # [H, dh]
    k = k_ref[0]                                    # [TS, KV, dh]
    v = v_ref[0]
    h, dh = q.shape
    ts, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(kvh, g, dh)

    s = jnp.einsum("kgd,skd->kgs", qg, k,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / (dh ** 0.5))
    kpos = si * ts + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ts), 2)
    s = jnp.where(kpos <= pos_ref[0, 0], s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[:], l_ref[:], acc_ref[:]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])               # [KV,G,TS]
    l_new = l_prev * scale + jnp.sum(p, axis=-1)
    acc_new = acc_prev * scale[..., None] + jnp.einsum(
        "kgs,skd->kgd", p, v, preferred_element_type=jnp.float32
    )
    m_ref[:], l_ref[:], acc_ref[:] = m_new, l_new, acc_new

    @pl.when(si == n_s - 1)
    def _emit():
        out = acc_ref[:] / jnp.maximum(l_ref[:], 1e-30)[..., None]
        o_ref[0] = out.reshape(h, dh).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_s"))
def decode_attention(q: Array, k: Array, v: Array, pos: Array,
                     interpret: bool = False, tile_s: int = TILE_S) -> Array:
    """q [B,H,dh]; k/v [B,S,KV,dh]; pos scalar int32 -> [B,H,dh]."""
    b, h, dh = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    if s_len % tile_s:
        pad = tile_s - s_len % tile_s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_len = k.shape[1]
    g = h // kvh
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=(b, s_len // tile_s),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, si: (0, 0)),
            pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, tile_s, kvh, dh), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, tile_s, kvh, dh), lambda bi, si: (bi, si, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kvh, g), jnp.float32),
            pltpu.VMEM((kvh, g), jnp.float32),
            pltpu.VMEM((kvh, g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k, v)
