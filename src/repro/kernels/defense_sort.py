"""Pallas coordinate-sort kernel for the digital screening defenses.

Coordinate-wise median and trimmed-mean both reduce a sorted-per-coordinate
view of the gathered [U, D] gradient slab (core/defenses.py).  `jnp.sort`
along the worker axis lowers to a generic variadic sort that moves the slab
through HBM more than once at large D; but U is tiny (the paper runs U=10)
and STATIC, so the sort is better expressed as a fixed odd-even transposition
network over the worker axis — U compare-exchange passes of `minimum`/
`maximum` on [TILE_D]-wide rows, fully unrolled at trace time, one pass over
the slab in VMEM.

The unrolled network is an O(U^2) trace, so it is CAPPED at U <=
UNROLL_MAX_U (32): at the paper's U=10 it is 45 min/max pairs, at U=1024 it
would be ~524k — a multi-minute trace for a worse schedule than a real
sort.  Above the cap, `sort_columns_bitonic` is the large-U successor: the
classic bitonic network expressed as O(log^2 U) whole-block stages, each
stage one roll + select + min/max over the [U_pad, TILE] block (U padded to
the next power of two with +inf, which ascending-sorts to the bottom rows
and is sliced away).  The stage count is static and tiny (log2(4096)^2 =
144), so the trace stays small while the data movement stays one VMEM pass
per tile.  Routing between the two (and the `jnp.sort` oracle) lives in
`core.defenses.sorted_columns`.

Shape contract and tiling mirror `floa_aggregate`:

  sort_columns          [U, D] -> [U, D]  ascending along axis 0 (U <= 32)
  sort_columns_bitonic  [U, D] -> [U, D]  ascending along axis 0
                                          (U padded to a power of two,
                                           U_pad <= BITONIC_MAX_U)

Grid is (D // TILE); the [U(_pad), TILE] block lives in VMEM (unrolled:
U<=32 x TILE_D=2048 f32 = 256 KiB; bitonic: the tile narrows as U_pad grows
— `bitonic_tile_d` keeps block x ~3 live temporaries inside the ~16 MiB
budget, bottoming out at the 128-lane minimum tile, which is what caps
U_pad at BITONIC_MAX_U=8192).  D is padded to the tile once, in the
un-jitted public wrappers, before the jitted pallas_call core (columns sort
independently, so zero-padded columns cannot perturb real ones; see the
D-padding recursion note in floa_aggregate.py).
The sweep engine's defense kernels call this per lane under `jax.vmap`
(grouped dispatch vmaps one family over its lane group); Pallas's batching
rule lifts the vmap into a leading grid dimension, so there is no separate
hand-written [S, U, D] kernel to keep in lockstep — the vmap route is
pinned against the batched `jnp.sort` oracle in tests/test_defense_sort.py.

The network uses `jnp.minimum`/`jnp.maximum` compare-exchanges: on finite
inputs it agrees with the `jnp.sort` oracle exactly (ties keep values, not
worker identity — coordinate-wise reductions never look at identity).  NaN
ordering is NOT the oracle's (sort places NaNs last; min/max propagate them
everywhere) — gradient slabs are finite, and the oracle contract in
tests/test_defense_sort.py is pinned on finite values only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TILE_D = 2048
# Largest U the fully-unrolled odd-even network may trace (O(U^2) min/max
# pairs); larger slabs route to the bitonic kernel or the jnp.sort oracle.
UNROLL_MAX_U = 32
# Largest padded U the bitonic kernel accepts: at the 128-lane minimum tile
# an [8192, 128] f32 block is 4 MiB, and the stage body keeps ~3 such
# temporaries live — beyond this the block cannot fit VMEM at any tile.
BITONIC_MAX_U = 8192


def _pad_last(x: Array, pad: int) -> Array:
    """Zero-pad the last axis by `pad` entries (no-op when pad == 0)."""
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _odd_even_sort(x: Array) -> Array:
    """Odd-even transposition network over axis 0 of a [U, T] block.

    U passes of adjacent compare-exchanges (even pairs, then odd pairs,
    alternating) sort any input of length U — the classic transposition-sort
    bound.  U is static, so the whole network unrolls at trace time into
    O(U^2 / 2) vectorized min/max pairs on [1, T] rows; there is no data-
    dependent control flow, which is exactly what the VPU wants.
    """
    u = x.shape[0]
    rows = [x[i:i + 1] for i in range(u)]  # [1, T] each (2-D for Mosaic)
    for p in range(u):
        for i in range(p % 2, u - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return jnp.concatenate(rows, axis=0) if u > 1 else rows[0]


def _kernel(x_ref, o_ref):
    x = x_ref[:].astype(jnp.float32)                # [U, TILE_D]
    o_ref[:] = _odd_even_sort(x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def _sort_columns_core(x: Array, interpret: bool, tile_d: int) -> Array:
    u, d = x.shape
    assert d % tile_d == 0, "core requires pre-padded D (see public wrapper)"
    return pl.pallas_call(
        _kernel,
        grid=(d // tile_d,),
        in_specs=[pl.BlockSpec((u, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((u, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((u, d), x.dtype),
        interpret=interpret,
    )(x)


def sort_columns(x: Array, interpret: bool = False,
                 tile_d: int = TILE_D) -> Array:
    """[U, D] -> [U, D], ascending along the worker axis (axis 0).

    U is bounded by UNROLL_MAX_U — the network fully unrolls at trace time,
    so an unbounded U is an O(U^2) trace-size bomb.  Large-U slabs belong to
    `sort_columns_bitonic` (the `core.defenses.sorted_columns` router picks
    for you)."""
    u, d = x.shape
    if u > UNROLL_MAX_U:
        raise ValueError(
            f"sort_columns unrolls an O(U^2) network: U={u} exceeds the "
            f"U<={UNROLL_MAX_U} bound — use sort_columns_bitonic (or the "
            f"jnp.sort oracle) for large worker populations")
    pad = -d % tile_d  # single pad before the jitted core
    out = _sort_columns_core(_pad_last(x, pad), interpret=interpret,
                             tile_d=tile_d)
    return out[:, :d] if pad else out


# ---------------------------------------------------- large-U bitonic stages


def bitonic_tile_d(u_pad: int) -> int:
    """Widest D tile whose [u_pad, tile] f32 block (x ~3 live stage
    temporaries) stays inside the VMEM budget, floored at the 128-lane
    minimum tile."""
    return max(128, min(TILE_D, (1 << 19) // u_pad))


def _bitonic_stages(x: Array) -> Array:
    """Bitonic sorting network over axis 0 of an [N, T] block, N a power of
    two; ascending.

    The pairwise compare-exchange with partner ``l = i ^ j`` is vectorized
    as whole-block rolls: rows with ``i & j == 0`` pair downward (partner at
    i + j, i.e. roll(-j)), the rest pair upward (roll(+j)); the merge
    direction flips with ``i & k``.  Each of the log2(N)*(log2(N)+1)/2
    stages is one roll + two selects + min/max over the block — no
    data-dependent control flow, no per-row slicing, so the trace is
    O(log^2 N) whole-block ops instead of the unrolled network's O(N^2)
    pairs.

    Same tie/NaN semantics as the odd-even network (min/max compare-
    exchanges): exact `jnp.sort` agreement on finite inputs, finite-only
    contract (see the module docstring).
    """
    n = x.shape[0]
    assert n & (n - 1) == 0, f"bitonic stages need a power-of-two N, got {n}"
    if n == 1:
        return x
    i = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            is_first = (i & j) == 0            # partner sits at i + j
            partner = jnp.where(is_first, jnp.roll(x, -j, axis=0),
                                jnp.roll(x, j, axis=0))
            asc = (i & k) == 0                 # merge direction of this block
            keep_lo = is_first == asc
            x = jnp.where(keep_lo, jnp.minimum(x, partner),
                          jnp.maximum(x, partner))
            j //= 2
        k *= 2
    return x


def _bitonic_kernel(x_ref, o_ref):
    x = x_ref[:].astype(jnp.float32)                # [U_pad, tile]
    o_ref[:] = _bitonic_stages(x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def _sort_columns_bitonic_core(x: Array, interpret: bool,
                               tile_d: int) -> Array:
    u, d = x.shape
    assert d % tile_d == 0, "core requires pre-padded D (see public wrapper)"
    return pl.pallas_call(
        _bitonic_kernel,
        grid=(d // tile_d,),
        in_specs=[pl.BlockSpec((u, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((u, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((u, d), x.dtype),
        interpret=interpret,
    )(x)


def sort_columns_bitonic(x: Array, interpret: bool = False,
                         tile_d: int = 0) -> Array:
    """[U, D] -> [U, D], ascending along the worker axis — the large-U
    successor to `sort_columns`.

    U is padded to the next power of two with +inf rows (they ascending-sort
    to the bottom and are sliced away), D to the tile; both pads happen once
    here, outside the jitted core.  tile_d=0 picks the VMEM-fitting width
    via `bitonic_tile_d`."""
    u, d = x.shape
    u_pad = 1 << max(u - 1, 0).bit_length()         # next power of two
    if u_pad > BITONIC_MAX_U:
        raise ValueError(
            f"sort_columns_bitonic: padded U={u_pad} exceeds "
            f"BITONIC_MAX_U={BITONIC_MAX_U} (the [U_pad, 128] block no "
            f"longer fits VMEM) — use the jnp.sort oracle")
    tile_d = tile_d or bitonic_tile_d(u_pad)
    dpad = -d % tile_d
    xp = _pad_last(x, dpad)
    if u_pad > u:
        fill = jnp.full((u_pad - u, xp.shape[1]), jnp.inf, xp.dtype)
        xp = jnp.concatenate([xp, fill], axis=0)
    out = _sort_columns_bitonic_core(xp, interpret=interpret, tile_d=tile_d)
    return out[:u, :d]
