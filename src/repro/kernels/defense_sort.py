"""Pallas coordinate-sort kernel for the digital screening defenses.

Coordinate-wise median and trimmed-mean both reduce a sorted-per-coordinate
view of the gathered [U, D] gradient slab (core/defenses.py).  `jnp.sort`
along the worker axis lowers to a generic variadic sort that moves the slab
through HBM more than once at large D; but U is tiny (the paper runs U=10)
and STATIC, so the sort is better expressed as a fixed odd-even transposition
network over the worker axis — U compare-exchange passes of `minimum`/
`maximum` on [TILE_D]-wide rows, fully unrolled at trace time, one pass over
the slab in VMEM.

Shape contract and tiling mirror `floa_aggregate`:

  sort_columns  [U, D] -> [U, D]  ascending along axis 0

Grid is (D // TILE_D); the [U, TILE_D] block lives in VMEM (U<=32,
TILE_D=2048, f32: 256 KiB — comfortably inside the VMEM budget).  D is
padded to the tile once, in the un-jitted public wrapper, before the jitted
pallas_call core (columns sort independently, so zero-padded columns cannot
perturb real ones; see the D-padding recursion note in floa_aggregate.py).
The sweep engine's defense kernels call this per lane under `jax.vmap`
(grouped dispatch vmaps one family over its lane group); Pallas's batching
rule lifts the vmap into a leading grid dimension, so there is no separate
hand-written [S, U, D] kernel to keep in lockstep — the vmap route is
pinned against the batched `jnp.sort` oracle in tests/test_defense_sort.py.

The network uses `jnp.minimum`/`jnp.maximum` compare-exchanges: on finite
inputs it agrees with the `jnp.sort` oracle exactly (ties keep values, not
worker identity — coordinate-wise reductions never look at identity).  NaN
ordering is NOT the oracle's (sort places NaNs last; min/max propagate them
everywhere) — gradient slabs are finite, and the oracle contract in
tests/test_defense_sort.py is pinned on finite values only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TILE_D = 2048


def _pad_last(x: Array, pad: int) -> Array:
    """Zero-pad the last axis by `pad` entries (no-op when pad == 0)."""
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _odd_even_sort(x: Array) -> Array:
    """Odd-even transposition network over axis 0 of a [U, T] block.

    U passes of adjacent compare-exchanges (even pairs, then odd pairs,
    alternating) sort any input of length U — the classic transposition-sort
    bound.  U is static, so the whole network unrolls at trace time into
    O(U^2 / 2) vectorized min/max pairs on [1, T] rows; there is no data-
    dependent control flow, which is exactly what the VPU wants.
    """
    u = x.shape[0]
    rows = [x[i:i + 1] for i in range(u)]  # [1, T] each (2-D for Mosaic)
    for p in range(u):
        for i in range(p % 2, u - 1, 2):
            lo = jnp.minimum(rows[i], rows[i + 1])
            hi = jnp.maximum(rows[i], rows[i + 1])
            rows[i], rows[i + 1] = lo, hi
    return jnp.concatenate(rows, axis=0) if u > 1 else rows[0]


def _kernel(x_ref, o_ref):
    x = x_ref[:].astype(jnp.float32)                # [U, TILE_D]
    o_ref[:] = _odd_even_sort(x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_d"))
def _sort_columns_core(x: Array, interpret: bool, tile_d: int) -> Array:
    u, d = x.shape
    assert d % tile_d == 0, "core requires pre-padded D (see public wrapper)"
    return pl.pallas_call(
        _kernel,
        grid=(d // tile_d,),
        in_specs=[pl.BlockSpec((u, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((u, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((u, d), x.dtype),
        interpret=interpret,
    )(x)


def sort_columns(x: Array, interpret: bool = False,
                 tile_d: int = TILE_D) -> Array:
    """[U, D] -> [U, D], ascending along the worker axis (axis 0)."""
    u, d = x.shape
    pad = -d % tile_d  # single pad before the jitted core
    out = _sort_columns_core(_pad_last(x, pad), interpret=interpret,
                             tile_d=tile_d)
    return out[:, :d] if pad else out
