"""Closed-form convergence theory of the paper (Thms 2-3, Lemma 1, Remarks).

Everything is NumPy-scalar level (no jax needed) so benchmarks/tests can probe
the theory cheaply.  Notation matches the paper:

  U = M + N workers (M honest, N Byzantine), gradient dim D,
  sigma_i = Rayleigh scale of worker i's channel, p_i^max = max power,
  b0^2 = P0_max * lambda (CI amplitude), L = Lipschitz smoothness,
  delta^2 = per-worker gradient variance bound, eps = std bound, z = AWGN std.

CI  (Thm 2):  omega_CI   = M b0 - sum_n sqrt(pi sigma_n^2 p_n^max / (2D))
              Omega_CI   = (U+N) (U b0^2 + sum_n 2 sigma_n^2 p_n^max / D)
BEV (Thm 3):  omega_BEV  = sum_{i honest} sqrt(p_i^max pi/(2D)) sigma_i
                          - sum_{n byz}  sqrt(p_n^max pi/(2D)) sigma_n
              Omega_BEV  = (U+N) sum_{i=1..U} 2 sigma_i^2 p_i^max / D

Convergence iff  alpha^2 L/2 * Omega - alpha * omega < 0, i.e.
alpha < 2 omega / (L Omega) and omega > 0 (Remarks 1 & 4).

Attacker-count thresholds (iso case, Remarks 2 & 4):
  CI:  N <= U / (1 + sqrt(pi U));   BEV: N <= U/2.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


def _vec(x, u: int) -> list:
    if isinstance(x, (int, float)):
        return [float(x)] * u
    xs = list(map(float, x))
    assert len(xs) == u
    return xs


@dataclasses.dataclass(frozen=True)
class TheoryParams:
    num_workers: int
    num_attackers: int
    dim: int
    sigma: object = 1.0  # scalar or [U]
    p_max: object = 1.0  # scalar or [U]
    byzantine_idx: Sequence[int] = ()  # which workers attack; default first N

    def __post_init__(self):
        idx = tuple(self.byzantine_idx) or tuple(range(self.num_attackers))
        object.__setattr__(self, "byzantine_idx", idx)
        assert len(idx) == self.num_attackers

    @property
    def sigmas(self) -> list:
        return _vec(self.sigma, self.num_workers)

    @property
    def p_maxes(self) -> list:
        return _vec(self.p_max, self.num_workers)

    @property
    def honest_idx(self) -> tuple:
        byz = set(self.byzantine_idx)
        return tuple(i for i in range(self.num_workers) if i not in byz)


def ci_b0(tp: TheoryParams) -> float:
    """b0 = sqrt(P0_max * lambda) with lambda = 1/sum_i 1/(2 sigma_i^2)."""
    p0 = min(tp.p_maxes) / tp.dim
    lam = 1.0 / sum(1.0 / (2.0 * s**2) for s in tp.sigmas)
    return math.sqrt(p0 * lam)


def omega_ci(tp: TheoryParams) -> float:
    b0 = ci_b0(tp)
    m = tp.num_workers - tp.num_attackers
    atk = sum(
        math.sqrt(math.pi * tp.sigmas[n] ** 2 * tp.p_maxes[n] / (2.0 * tp.dim))
        for n in tp.byzantine_idx
    )
    return m * b0 - atk


def Omega_ci(tp: TheoryParams) -> float:
    b0 = ci_b0(tp)
    u, n = tp.num_workers, tp.num_attackers
    atk = sum(2.0 * tp.sigmas[i] ** 2 * tp.p_maxes[i] / tp.dim for i in tp.byzantine_idx)
    return (u + n) * (u * b0**2 + atk)


def omega_bev(tp: TheoryParams) -> float:
    def term(i):
        return math.sqrt(tp.p_maxes[i] * math.pi / (2.0 * tp.dim)) * tp.sigmas[i]

    return sum(term(i) for i in tp.honest_idx) - sum(
        term(n) for n in tp.byzantine_idx
    )


def Omega_bev(tp: TheoryParams) -> float:
    u, n = tp.num_workers, tp.num_attackers
    return (u + n) * sum(
        2.0 * tp.sigmas[i] ** 2 * tp.p_maxes[i] / tp.dim for i in range(u)
    )


def omega_Omega(tp: TheoryParams, policy: str):
    policy = policy.lower()
    if policy == "ci":
        return omega_ci(tp), Omega_ci(tp)
    if policy == "bev":
        return omega_bev(tp), Omega_bev(tp)
    if policy == "ef":
        # Ideal: coefficients 1/U each, no channel/noise: omega = 1, Omega = 1
        # in the normalized sense of Lemma 1 (omega^2 == Omega when N=0).
        return 1.0, 1.0
    raise ValueError(policy)


def lr_upper_bound(tp: TheoryParams, policy: str, lipschitz: float) -> float:
    """alpha < 2 omega / (L Omega) (Remarks 1 & 4).  <=0 means divergence."""
    w, W = omega_Omega(tp, policy)
    return 2.0 * w / (lipschitz * W)


def converges(tp: TheoryParams, policy: str, alpha: float, lipschitz: float) -> bool:
    """The paper's convergence condition alpha^2 L/2 Omega - alpha omega < 0."""
    w, W = omega_Omega(tp, policy)
    return alpha**2 * lipschitz / 2.0 * W - alpha * w < 0.0


def alpha_from_alpha_hat(tp: TheoryParams, policy: str, alpha_hat: float,
                         lipschitz: float = 1.0, total_steps: int = 1) -> float:
    """Paper §IV: experiments set the scaled rate alpha_hat = (Omega/omega) alpha
    (= abar/(L sqrt(T))).  Returns raw alpha.  omega<=0 -> returns alpha for
    |omega| so experiments can still *run* (and visibly diverge, as in Fig 3).
    """
    w, W = omega_Omega(tp, policy)
    w = abs(w) if w != 0 else 1e-12
    return alpha_hat * w / W


def max_attackers_ci_iso(u: int) -> float:
    """Remark 2's stated bound N <= U / (1 + sqrt(pi U)) (iso case).

    Note: this is the paper's (conservative, sufficient) bound.  Solving
    omega_CI > 0 exactly from eq. (21) in the iso case gives the slightly
    larger `max_attackers_ci_iso_exact` = U / (1 + sqrt(pi U)/2); both are
    far below BEV's U/2 — the paper's qualitative claim is unaffected.
    """
    return u / (1.0 + math.sqrt(math.pi * u))


def max_attackers_ci_iso_exact(u: int) -> float:
    """Exact iso-case CI threshold: omega_CI > 0  <=>  N < this."""
    return u / (1.0 + math.sqrt(math.pi * u) / 2.0)


def max_attackers_bev_iso(u: int) -> float:
    """Remark 4: N <= U/2."""
    return u / 2.0


def rate_bound(
    tp: TheoryParams,
    policy: str,
    lipschitz: float,
    f0_minus_fstar: float,
    delta2: float,
    eps_bound: float,
    noise_std: float,
    total_steps: int,
    alpha_bar: float,
) -> float:
    """Thm 2/3 RHS: the bound on E[ (1/T) sum ||g_t||^2 ].

    (1/sqrt(T)) * ( 2 L Omega / (omega^2 abar) (F0-F*) +
                    abar (delta^2 + eps^2 z^2 / Omega) ).
    Requires omega > 0 (otherwise the bound is vacuous -> returns inf).
    """
    w, W = omega_Omega(tp, policy)
    if w <= 0:
        return float("inf")
    t = float(total_steps)
    return (1.0 / math.sqrt(t)) * (
        2.0 * lipschitz * W / (w**2 * alpha_bar) * f0_minus_fstar
        + alpha_bar * (delta2 + eps_bound**2 * noise_std**2 / W)
    )
