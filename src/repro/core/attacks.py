"""Byzantine attack models (paper §III-B, Thm 1).

The strongest attack (Thm 1): attacker n computes its own honest gradient
g_{n,t} on its local data, then transmits

    ghat_{n,t} = -g_{n,t}                                   (eq. 17)
    phat_{n,t} = sqrt( p_n^max / (D (gbar_t^2 + eps_t^2)) ) (eq. 18)

i.e. the sign-flipped *unstandardized* gradient at the maximum power allowed by
the power accounting E[||phat ghat||^2] = phat^2 D (eps_t^2 + gbar_t^2) <= p^max
(eq. 32).  Crucially the attackers report *truthful* scalar stats during the
standardization round (to stay undetected), so gbar_t / eps_t are clean.

Plugging into the received signal (eq. 7), worker n's total contribution to the
de-standardized aggregate is

    - eps_t * phat_n |h_n| * g_{n,t}    (sign-flipped payload)
    + p_n |h_n| * gbar_t * 1            (PS's de-standardization bias: the PS
                                         *assumes* worker n used protocol power
                                         p_n and standardized transmission)

Ablation attacks (beyond the paper's worst case, for experiments):
  GAUSSIAN: transmit white noise at max power (unstructured jamming).
  SIGN_FLIP_PROTOCOL_POWER: -g at the *protocol* (standardized) power — a naive
    attacker that follows the power accounting of honest workers.
  NONE: behave honestly.

Adaptive attacks (the cohort acts on shared round state, so their payload is a
single rank-1 direction rather than per-worker gradients):
  COLLUDING: the round's Byzantine cohort agrees on ONE shared unit-RMS
    perturbation direction (drawn from a cohort-common key) and every member
    transmits it at max power sqrt(p_max / D) — the transmitted power meets
    eq. 32 with equality.  The received perturbation is
    eps_t * sum_{n in B} |h_n| sqrt(p_n^max / D) * d  (`colluding_dir_weight`).
  OMNISCIENT: attackers observe the round's honest mean and transmit its
    negation at the eq. 18 max accounting power phat — the adaptive
    generalization of the strongest attack (eq. 17 with ghat = -mean of the
    HONEST gradients instead of -g_n).  Received perturbation weight is
    sum_{n in B} (-eps_t phat_n |h_n|)  (`omniscient_dir_weight`); a cohort of
    size 1 on identical worker shards degenerates to STRONGEST exactly.

Both adaptive attacks need round state the stateless `signed_coefficients`
path cannot carry (the cohort key / the honest mean of the round's slab), so
the branching path models only their per-worker payload (zero) + bias; the
full directional term lives in the sweep engine (fl/sweep.py), which pins the
degenerate contracts in tests/test_scenario_axes.py.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import ChannelConfig
from repro.core.power_control import PowerConfig, transmit_amplitudes

Array = jax.Array


class AttackType(str, enum.Enum):
    NONE = "none"
    STRONGEST = "strongest"  # Thm 1: sign flip at max accounting power
    SIGN_FLIP_PROTOCOL_POWER = "sign_flip_protocol_power"
    GAUSSIAN = "gaussian"
    COLLUDING = "colluding"    # shared rank-1 direction at max power
    OMNISCIENT = "omniscient"  # negated honest mean at eq. 18 max power


# Attacks whose payload is one shared direction (rank-1 across the cohort)
# instead of per-worker gradients; the sweep engine injects it after the OTA
# combine.
DIRECTIONAL_ATTACKS = (AttackType.COLLUDING, AttackType.OMNISCIENT)


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """byzantine_mask: tuple of U bools, True = worker is Byzantine."""

    attack: AttackType = AttackType.NONE
    byzantine_mask: Tuple[bool, ...] = ()

    @property
    def num_attackers(self) -> int:
        return int(sum(self.byzantine_mask))

    def mask(self) -> Array:
        return jnp.asarray(self.byzantine_mask, dtype=bool)


def first_n_mask(num_workers: int, n: int) -> Tuple[bool, ...]:
    return tuple(i < n for i in range(num_workers))


def strongest_attack_amplitude(
    p_max: Array, dim, gbar: Array, eps2: Array
) -> Array:
    """phat_n of eq. (18).  p_max [U] (or scalar), gbar/eps2 round scalars,
    dim a static int or traced scalar (the sweep path passes an array)."""
    return jnp.sqrt(p_max / (dim * (gbar**2 + eps2)))


def signed_coefficients(
    h_abs: Array,
    power: PowerConfig,
    channel: ChannelConfig,
    attack: AttackConfig,
    gbar: Array,
    eps2: Array,
) -> Tuple[Array, Array]:
    """Per-worker signed payload coefficients + de-standardization bias weight.

    Returns (s, bias_w):
      s[i]      multiplies worker i's raw gradient g_i in the aggregate:
                  honest:  p_i |h_i|                    (eq. 7, first term)
                  strongest attacker: -eps_t phat_n |h_n|  (eq. 7, second term,
                                                          with ghat = -g)
      bias_w    scalar sum_{n in attackers} p_n |h_n| multiplying gbar_t * 1
                (eq. 7, third term; honest workers' gbar terms cancel exactly
                in the de-standardization, attackers' do not because they did
                not actually standardize).
    For GAUSSIAN attackers s[n] = 0 (their payload carries no gradient); the
    caller injects their jamming noise separately via `gaussian_jam_std`.
    """
    eps = jnp.sqrt(eps2)
    honest_s = transmit_amplitudes(h_abs, power, channel) * h_abs
    mask = attack.mask()
    if attack.attack == AttackType.NONE or attack.num_attackers == 0:
        return honest_s, jnp.zeros(())

    if attack.attack == AttackType.STRONGEST:
        phat = strongest_attack_amplitude(power.p_maxes(), power.dim, gbar, eps2)
        attacker_s = -eps * phat * h_abs
    elif attack.attack == AttackType.SIGN_FLIP_PROTOCOL_POWER:
        attacker_s = -honest_s
    elif attack.attack in (AttackType.GAUSSIAN,) + DIRECTIONAL_ATTACKS:
        # No per-worker gradient payload: GAUSSIAN jams (gaussian_jam_std),
        # COLLUDING/OMNISCIENT transmit one shared direction (the
        # *_dir_weight helpers; the sweep engine owns the direction itself).
        attacker_s = jnp.zeros_like(honest_s)
    else:
        raise ValueError(f"unknown attack {attack.attack}")

    s = jnp.where(mask, attacker_s, honest_s)
    # The PS de-standardizes assuming every worker used protocol power p_i.
    bias_w = jnp.sum(jnp.where(mask, honest_s, 0.0))
    if attack.attack == AttackType.SIGN_FLIP_PROTOCOL_POWER:
        # These attackers DO standardize (just flip sign), so their gbar term
        # cancels the PS bias exactly as for honest workers.
        bias_w = jnp.zeros(())
    return s, bias_w


def jam_std_arrays(
    h_abs: Array, p_maxes: Array, dim, mask: Array, eps2: Array
) -> Array:
    """GAUSSIAN jamming std from raw arrays (shared with core.scenario):
    max-power white noise from masked workers, scaled by eps_t."""
    amp = jnp.sqrt(p_maxes / dim) * h_abs  # max power jam
    return jnp.sqrt(eps2 * jnp.sum(jnp.where(mask, amp, 0.0) ** 2))


def colluding_dir_weight(
    h_abs: Array, p_maxes: Array, dim, mask: Array, eps2: Array
) -> Array:
    """Received weight of the COLLUDING cohort's shared unit-RMS direction d:
    every masked worker transmits sqrt(p_max/D) * d (eq. 32 with equality,
    since E||sqrt(p/D) d||^2 = (p/D) * D = p_max), the MAC superposes their
    |h|-scaled copies, and the PS's de-standardization multiplies by eps_t:

        weight = eps_t * sum_{n in B} |h_n| sqrt(p_n^max / D).
    """
    amp = jnp.sqrt(p_maxes / dim)
    return jnp.sqrt(eps2) * jnp.sum(jnp.where(mask, amp * h_abs, 0.0))


def omniscient_dir_weight(
    h_abs: Array, p_maxes: Array, dim, mask: Array, gbar: Array, eps2: Array
) -> Array:
    """Received weight of the OMNISCIENT cohort's shared payload (the negated
    honest mean, transmitted raw at the eq. 18 amplitude phat — the same power
    accounting as the strongest attack, eq. 32 with equality):

        weight = sum_{n in B} (-eps_t phat_n |h_n|),

    i.e. exactly the strongest attack's per-worker coefficient summed over the
    cohort — which is what makes a cohort of size 1 on identical shards
    degenerate to STRONGEST.
    """
    phat = strongest_attack_amplitude(p_maxes, dim, gbar, eps2)
    return -jnp.sqrt(eps2) * jnp.sum(jnp.where(mask, phat * h_abs, 0.0))


def gaussian_jam_std(
    h_abs: Array, power: PowerConfig, attack: AttackConfig, eps2: Array
) -> Array:
    """Std of the extra white noise injected by GAUSSIAN attackers, post
    de-standardization (scaled by eps_t like any received symbol)."""
    if attack.attack != AttackType.GAUSSIAN or attack.num_attackers == 0:
        return jnp.zeros(())
    return jam_std_arrays(h_abs, power.p_maxes(), float(power.dim),
                          attack.mask(), eps2)
