"""Byzantine attack models (paper §III-B, Thm 1).

The strongest attack (Thm 1): attacker n computes its own honest gradient
g_{n,t} on its local data, then transmits

    ghat_{n,t} = -g_{n,t}                                   (eq. 17)
    phat_{n,t} = sqrt( p_n^max / (D (gbar_t^2 + eps_t^2)) ) (eq. 18)

i.e. the sign-flipped *unstandardized* gradient at the maximum power allowed by
the power accounting E[||phat ghat||^2] = phat^2 D (eps_t^2 + gbar_t^2) <= p^max
(eq. 32).  Crucially the attackers report *truthful* scalar stats during the
standardization round (to stay undetected), so gbar_t / eps_t are clean.

Plugging into the received signal (eq. 7), worker n's total contribution to the
de-standardized aggregate is

    - eps_t * phat_n |h_n| * g_{n,t}    (sign-flipped payload)
    + p_n |h_n| * gbar_t * 1            (PS's de-standardization bias: the PS
                                         *assumes* worker n used protocol power
                                         p_n and standardized transmission)

Ablation attacks (beyond the paper's worst case, for experiments):
  GAUSSIAN: transmit white noise at max power (unstructured jamming).
  SIGN_FLIP_PROTOCOL_POWER: -g at the *protocol* (standardized) power — a naive
    attacker that follows the power accounting of honest workers.
  NONE: behave honestly.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import ChannelConfig
from repro.core.power_control import PowerConfig, transmit_amplitudes

Array = jax.Array


class AttackType(str, enum.Enum):
    NONE = "none"
    STRONGEST = "strongest"  # Thm 1: sign flip at max accounting power
    SIGN_FLIP_PROTOCOL_POWER = "sign_flip_protocol_power"
    GAUSSIAN = "gaussian"


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """byzantine_mask: tuple of U bools, True = worker is Byzantine."""

    attack: AttackType = AttackType.NONE
    byzantine_mask: Tuple[bool, ...] = ()

    @property
    def num_attackers(self) -> int:
        return int(sum(self.byzantine_mask))

    def mask(self) -> Array:
        return jnp.asarray(self.byzantine_mask, dtype=bool)


def first_n_mask(num_workers: int, n: int) -> Tuple[bool, ...]:
    return tuple(i < n for i in range(num_workers))


def strongest_attack_amplitude(
    p_max: Array, dim, gbar: Array, eps2: Array
) -> Array:
    """phat_n of eq. (18).  p_max [U] (or scalar), gbar/eps2 round scalars,
    dim a static int or traced scalar (the sweep path passes an array)."""
    return jnp.sqrt(p_max / (dim * (gbar**2 + eps2)))


def signed_coefficients(
    h_abs: Array,
    power: PowerConfig,
    channel: ChannelConfig,
    attack: AttackConfig,
    gbar: Array,
    eps2: Array,
) -> Tuple[Array, Array]:
    """Per-worker signed payload coefficients + de-standardization bias weight.

    Returns (s, bias_w):
      s[i]      multiplies worker i's raw gradient g_i in the aggregate:
                  honest:  p_i |h_i|                    (eq. 7, first term)
                  strongest attacker: -eps_t phat_n |h_n|  (eq. 7, second term,
                                                          with ghat = -g)
      bias_w    scalar sum_{n in attackers} p_n |h_n| multiplying gbar_t * 1
                (eq. 7, third term; honest workers' gbar terms cancel exactly
                in the de-standardization, attackers' do not because they did
                not actually standardize).
    For GAUSSIAN attackers s[n] = 0 (their payload carries no gradient); the
    caller injects their jamming noise separately via `gaussian_jam_std`.
    """
    eps = jnp.sqrt(eps2)
    honest_s = transmit_amplitudes(h_abs, power, channel) * h_abs
    mask = attack.mask()
    if attack.attack == AttackType.NONE or attack.num_attackers == 0:
        return honest_s, jnp.zeros(())

    if attack.attack == AttackType.STRONGEST:
        phat = strongest_attack_amplitude(power.p_maxes(), power.dim, gbar, eps2)
        attacker_s = -eps * phat * h_abs
    elif attack.attack == AttackType.SIGN_FLIP_PROTOCOL_POWER:
        attacker_s = -honest_s
    elif attack.attack == AttackType.GAUSSIAN:
        attacker_s = jnp.zeros_like(honest_s)
    else:
        raise ValueError(f"unknown attack {attack.attack}")

    s = jnp.where(mask, attacker_s, honest_s)
    # The PS de-standardizes assuming every worker used protocol power p_i.
    bias_w = jnp.sum(jnp.where(mask, honest_s, 0.0))
    if attack.attack == AttackType.SIGN_FLIP_PROTOCOL_POWER:
        # These attackers DO standardize (just flip sign), so their gbar term
        # cancels the PS bias exactly as for honest workers.
        bias_w = jnp.zeros(())
    return s, bias_w


def jam_std_arrays(
    h_abs: Array, p_maxes: Array, dim, mask: Array, eps2: Array
) -> Array:
    """GAUSSIAN jamming std from raw arrays (shared with core.scenario):
    max-power white noise from masked workers, scaled by eps_t."""
    amp = jnp.sqrt(p_maxes / dim) * h_abs  # max power jam
    return jnp.sqrt(eps2 * jnp.sum(jnp.where(mask, amp, 0.0) ** 2))


def gaussian_jam_std(
    h_abs: Array, power: PowerConfig, attack: AttackConfig, eps2: Array
) -> Array:
    """Std of the extra white noise injected by GAUSSIAN attackers, post
    de-standardization (scaled by eps_t like any received symbol)."""
    if attack.attack != AttackType.GAUSSIAN or attack.num_attackers == 0:
        return jnp.zeros(())
    return jam_std_arrays(h_abs, power.p_maxes(), float(power.dim),
                          attack.mask(), eps2)
