"""Gradient standardization for analog transmission (paper §II-B, eq. 3 & 7).

Before each round every worker estimates the scalar mean/variance of its own
gradient (over the D entries), the PS averages them into global stats
(gbar_t, eps_t^2), broadcasts them back, and workers transmit

    gtilde_i = (g_i - gbar_t * 1) / eps_t .                  (eq. 3)

The PS de-standardizes the received superposition y_t as

    gagg = eps_t * y_t + (sum_i p_i |h_i|) * gbar_t * 1 .    (eq. 7)

For honest workers the two gbar terms cancel per worker, leaving
sum_m p_m|h_m| g_m; attackers' terms do not cancel (see attacks.py).

All helpers operate on gradient *pytrees* so they compose with arbitrary model
parameter structures; stats are computed with f32 accumulators and lower to a
handful of scalar all-reduces on a sharded mesh (the paper assumes this side
channel is noise-free — two symbols per round).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def tree_size(tree) -> int:
    """Total number of scalar entries D across all leaves (static)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def per_worker_scalar_stats(grads_u) -> Tuple[Array, Array]:
    """(gbar_i, eps2_i) per worker from stacked per-worker gradients.

    grads_u: pytree whose leaves have a leading U axis ([U, ...]).
    Returns gbar [U] and eps2 [U] — the per-worker mean and (biased) variance
    of the D gradient entries, exactly the stats workers report in §II-B.
    """
    leaves = jax.tree_util.tree_leaves(grads_u)
    u = leaves[0].shape[0]
    d = sum(int(x.size) // u for x in leaves)
    s1 = sum(jnp.sum(x.astype(jnp.float32).reshape(u, -1), axis=1) for x in leaves)
    s2 = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)).reshape(u, -1), axis=1)
        for x in leaves
    )
    gbar = s1 / d
    eps2 = jnp.maximum(s2 / d - gbar**2, 1e-20)
    return gbar, eps2


def flat_scalar_stats(flat, sizes=None) -> Tuple[Array, Array]:
    """`per_worker_scalar_stats` for an already-flattened [U, D] gradient.

    The flat-state sweep engine keeps per-worker gradients as one [U, D]
    matrix and never materializes the pytree, so the stats have to come off
    the flat rows.  When `sizes` (the per-leaf entry counts of the original
    pytree, in flatten order) is given, the reduction is performed per leaf
    segment and the partial sums are combined in the same order as the
    pytree path — keeping the floating-point reduction tree identical to
    `per_worker_scalar_stats` so the two paths agree bitwise, not just
    approximately.  With sizes=None the whole row is reduced at once.
    """
    d = flat.shape[-1]
    f = flat.astype(jnp.float32)
    segs = [f]
    if sizes is not None:
        off, segs = 0, []
        for n in sizes:
            segs.append(f[..., off:off + n])
            off += n
        assert off == d, f"leaf sizes sum to {off}, flat D is {d}"
    s1 = sum(jnp.sum(x, axis=-1) for x in segs)
    s2 = sum(jnp.sum(jnp.square(x), axis=-1) for x in segs)
    gbar = s1 / d
    eps2 = jnp.maximum(s2 / d - gbar**2, 1e-20)
    return gbar, eps2


def flat_partial_stats(flat) -> Tuple[Array, Array]:
    """Per-shard partial sums (s1, s2) for a model-sharded flat gradient.

    Under a ("model",)-sharded sweep each shard holds a [U, D_loc] column
    block of the flat [U, D(+pad)] gradient; the scalar stats of the FULL
    row are recovered by psum-ing these partials over the "model" axis and
    finishing with `stats_from_partials`:

        s1, s2 = flat_partial_stats(flat_local)        # shard-local
        s1 = jax.lax.psum(s1, "model")                 # two scalars per row
        s2 = jax.lax.psum(s2, "model")
        gbar, eps2 = stats_from_partials(s1, s2, d)    # d = REAL (unpadded) D

    Numerical contract: ghost pad columns are zero-filled, so they
    contribute exactly 0.0 to both partial sums — padding never perturbs
    the stats.  The psum reduces the per-shard partials in mesh order,
    which is a DIFFERENT fp reduction tree from the unsharded single-sum
    `flat_scalar_stats`, so the sharded stats agree to rtol (f32 summation
    reassociation), not bitwise.  Bitwise equality is strict_numerics'
    job: that mode all-gathers the slab and replays `flat_scalar_stats`
    verbatim on full rows, sidestepping the partial-sum tree entirely.
    """
    f = flat.astype(jnp.float32)
    return jnp.sum(f, axis=-1), jnp.sum(jnp.square(f), axis=-1)


def stats_from_partials(s1: Array, s2: Array, d: int) -> Tuple[Array, Array]:
    """Finish `flat_partial_stats`: same mean/variance epilogue as
    `flat_scalar_stats` (including the 1e-20 variance floor), applied to
    already-reduced partial sums.  `d` is the REAL (unpadded) entry count."""
    gbar = s1 / d
    eps2 = jnp.maximum(s2 / d - gbar**2, 1e-20)
    return gbar, eps2


def global_stats(gbar_i: Array, eps2_i: Array) -> Tuple[Array, Array]:
    """PS-side averaging: gbar_t = mean_i gbar_i, eps_t^2 = mean_i eps2_i."""
    return jnp.mean(gbar_i), jnp.mean(eps2_i)


def masked_global_stats(gbar_i: Array, eps2_i: Array,
                        mask: Array) -> Tuple[Array, Array]:
    """`global_stats` over the participating workers only (K-of-U sampling:
    non-participants never report, so the PS averages the K masked entries).

    Computed as mean(where(mask, x, 0)) * (U / count) rather than
    sum(where)/count: at a full mask the scale is exactly 1.0, making this
    BITWISE-identical to `global_stats` under jit (a sum/traced-count
    spelling is not — XLA strength-reduces mean's divide-by-constant into a
    reciprocal multiply, which rounds differently from a true divide).  The
    K=U == full-participation sweep contract rests on this.
    """
    u = mask.shape[-1]
    scale = u / jnp.sum(mask.astype(jnp.float32))
    return (jnp.mean(jnp.where(mask, gbar_i, 0.0)) * scale,
            jnp.mean(jnp.where(mask, eps2_i, 0.0)) * scale)


def standardize(tree, gbar: Array, eps2: Array):
    """eq. (3): (g - gbar 1) / eps, elementwise over the pytree."""
    inv = jax.lax.rsqrt(eps2)
    return jax.tree_util.tree_map(lambda g: (g - gbar) * inv, tree)


def destandardize(tree, coeff_sum: Array, gbar: Array, eps2: Array):
    """eq. (7): eps * y + coeff_sum * gbar * 1, elementwise over the pytree."""
    eps = jnp.sqrt(eps2)
    return jax.tree_util.tree_map(lambda y: eps * y + coeff_sum * gbar, tree)
