"""Core FLOA library: the paper's contribution as composable JAX modules."""
from repro.core.aggregation import (
    FLOAConfig,
    aggregate,
    batched_floa_combine,
    flatten_worker_grads,
    floa_grad,
    mean_aggregate,
    per_worker_grads,
)
from repro.core.attacks import AttackConfig, AttackType, first_n_mask
from repro.core.channel import ChannelConfig, noise_std_for_snr, sample_channel_gains
from repro.core.power_control import Policy, PowerConfig
from repro.core.scenario import (
    DEFENSE_CODES,
    DefenseSpec,
    ScenarioParams,
    scenario_coefficients,
)
from repro.core.defenses import digital_aggregate, make_flat_defense_selector

__all__ = [
    "FLOAConfig", "aggregate", "floa_grad", "mean_aggregate", "per_worker_grads",
    "batched_floa_combine", "flatten_worker_grads",
    "AttackConfig", "AttackType", "first_n_mask",
    "ChannelConfig", "noise_std_for_snr", "sample_channel_gains",
    "Policy", "PowerConfig",
    "ScenarioParams", "scenario_coefficients",
    "DEFENSE_CODES", "DefenseSpec",
    "digital_aggregate", "make_flat_defense_selector",
]
