"""Wireless channel model for FLOA (paper §II-B).

Block Rayleigh fading: the channel gain of worker i at round t is
|h_{i,t}| ~ Rayleigh(scale=sigma_i), i.e. h ~ CN(0, 2 sigma_i^2) with
E[|h|]   = sigma_i * sqrt(pi/2)          (used in Thm 2/3, eqs. 21/25)
E[|h|^2] = 2 sigma_i^2                   (so |h|^2 ~ Exp(mean 2 sigma_i^2),
                                          lambda_i = 1/(2 sigma_i^2), paper §II-B.1)

Channels are resampled independently every round (block fading) and are known
perfectly at workers and PS (perfect CSI; the phase is pre-compensated at the
workers so only |h| matters — exactly the paper's model).

Time-varying extension (beyond the paper's block-i.i.d. model): Gauss-Markov
fading with per-round correlation rho,

    h_t = rho * h_{t-1} + sqrt(1 - rho^2) * w_t,   w_t ~ CN(0, 2 sigma^2),

on the COMPLEX gain (kept as a [..., 2] re/im state so |h_t| stays Rayleigh
under the stationary law: each component is N(0, sigma^2) at every t).
rho = 0 degenerates to the i.i.d. model; the sweep engine keeps rho = 0 lanes
bitwise on the legacy `rayleigh_gains` draw (tests/test_scenario_axes.py).

AWGN: z_t ~ N(0, z^2 I_D) added to the received superposition.  The paper sets
the receive SNR via p_max/(D z^2) = 10 dB; `noise_std_for_snr` inverts that.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Static description of the multiple-access channel.

    sigma: per-worker Rayleigh scale sigma_i (scalar broadcast or [U] vector).
    noise_std: AWGN std z (per received symbol).
    markov_rho: Gauss-Markov round-to-round fading correlation in [0, 1);
        0 (default) is the paper's block-i.i.d. model.
    """

    num_workers: int
    sigma: Union[float, tuple] = 1.0
    noise_std: float = 0.0
    markov_rho: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.markov_rho < 1.0:
            raise ValueError(
                f"markov_rho must be in [0, 1), got {self.markov_rho} "
                f"(rho = 1 freezes the channel forever; use a static sigma "
                f"instead)")

    def sigmas(self) -> Array:
        s = jnp.asarray(self.sigma, dtype=jnp.float32)
        return jnp.broadcast_to(s, (self.num_workers,))


def rayleigh_gains(key: Array, sigmas: Array) -> Array:
    """|h| = sigma * sqrt(2 * E), E ~ Exp(1)  (so |h|^2 ~ Exp(mean 2 sigma^2)).

    The one Rayleigh recipe shared by the dataclass path (below) and the
    traceable sweep path (core.scenario.sample_gains) — per-key draws must
    stay identical between the two, so neither may fork its own version.
    """
    e = jax.random.exponential(key, sigmas.shape, dtype=jnp.float32)
    return sigmas * jnp.sqrt(2.0 * e)


def sample_channel_gains(key: Array, cfg: ChannelConfig) -> Array:
    """Draw |h_{i,t}| for all U workers for one round.  Shape [U]."""
    return rayleigh_gains(key, cfg.sigmas())


def complex_gain_init(key: Array, sigmas: Array) -> Array:
    """Stationary complex-gain state for Gauss-Markov fading: re/im each
    N(0, sigma^2), shape sigmas.shape + (2,) — so `complex_gain_abs` of the
    init is Rayleigh(sigma), the same marginal as `rayleigh_gains`."""
    z = jax.random.normal(key, sigmas.shape + (2,), dtype=jnp.float32)
    return sigmas[..., None] * z


def gauss_markov_step(h_prev: Array, innovation: Array, rho) -> Array:
    """One Gauss-Markov update h_t = rho h_{t-1} + sqrt(1-rho^2) w_t.

    h_prev / innovation: [..., 2] complex states; `innovation` must be a
    fresh draw of the SAME stationary law (`complex_gain_init`), which keeps
    every marginal Rayleigh.  rho may be a traced per-lane scalar (broadcast
    against the state), so one trace serves a whole sweep's lane axis.
    """
    return rho * h_prev + jnp.sqrt(jnp.maximum(1.0 - rho**2, 0.0)) * innovation


def complex_gain_abs(h: Array) -> Array:
    """|h| from the [..., 2] re/im state."""
    return jnp.sqrt(jnp.sum(jnp.square(h), axis=-1))


def expected_abs_gain(cfg: ChannelConfig) -> Array:
    """E[|h_i|] = sigma_i sqrt(pi/2), vector [U]."""
    return cfg.sigmas() * jnp.sqrt(jnp.pi / 2.0)


def expected_sq_gain(cfg: ChannelConfig) -> Array:
    """E[|h_i|^2] = 2 sigma_i^2, vector [U]."""
    return 2.0 * cfg.sigmas() ** 2


def min_sq_gain_from_sigmas(sigmas: Array) -> Array:
    """E[min_i |h_i|^2] = 1 / sum_i lambda_i with lambda_i = 1/(2 sigma_i^2).

    Array form shared by the dataclass path (below) and the traceable sweep
    path (core.scenario): the minimum of independent exponentials is
    exponential with rate = sum of rates.
    """
    lam = 1.0 / (2.0 * sigmas**2)
    return 1.0 / jnp.sum(lam)


def expected_min_sq_gain(cfg: ChannelConfig) -> Array:
    """The `lambda` used by the CI scaling factor b0^2 = P0_max * lambda
    (paper eq. 9-10)."""
    return min_sq_gain_from_sigmas(cfg.sigmas())


def noise_std_for_snr(p_max: float, dim: int, snr_db: float) -> float:
    """Solve p_max / (D z^2) = SNR for z (paper §IV: SNR = 10 dB)."""
    snr = 10.0 ** (snr_db / 10.0)
    return float((p_max / (dim * snr)) ** 0.5)
