"""Traceable scenario parameters: `FLOAConfig` as a struct-of-arrays pytree.

`FLOAConfig` is a frozen dataclass whose policy/attack fields steer Python
branches at trace time — perfect for one jit per scenario, useless for a
`vmap` over a *stacked* scenario axis (the paper's Figs. 1-4 are exactly such
grids: attack type x attacker count x power policy x seed).  This module is
the bridge:

  ScenarioParams      every FLOAConfig field that varies per scenario, as
                      arrays (enums -> int32 codes, masks/sigmas -> vectors),
                      so a whole sweep stacks into one [S, ...] pytree.
  from_floa           FLOAConfig (+ per-scenario alpha) -> ScenarioParams.
  scenario_coefficients
                      branchless re-derivation of channel.py / power_control.py
                      / attacks.py for ONE scenario — policy and attack
                      selection via jnp.where on the code arrays, so the same
                      function vmaps cleanly over the stacked axis.

The branchless path must agree with the branching modules exactly; the
per-combination equivalence test in tests/test_sweep.py is the contract.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as A
from repro.core.channel import rayleigh_gains
from repro.core.power_control import Policy, ci_b0_arrays, max_amplitude_arrays

Array = jax.Array

POLICY_CODES = {
    Policy.CI: 0,
    Policy.BEV: 1,
    Policy.EF: 2,
    Policy.TRUNCATED_CI: 3,
}
ATTACK_CODES = {
    A.AttackType.NONE: 0,
    A.AttackType.STRONGEST: 1,
    A.AttackType.SIGN_FLIP_PROTOCOL_POWER: 2,
    A.AttackType.GAUSSIAN: 3,
    A.AttackType.COLLUDING: 4,
    A.AttackType.OMNISCIENT: 5,
}
_CI, _BEV, _EF, _TCI = 0, 1, 2, 3
_NONE, _STRONGEST, _SIGN_FLIP, _GAUSSIAN = 0, 1, 2, 3
_COLLUDING, _OMNISCIENT = 4, 5

# Defense-code lane axis: 0 selects the analog FLOA combine (the paper's
# scheme); every other code selects a digital screening defense applied to
# the gathered [U, D] per-worker gradient slab (core/defenses.py).  "krum"
# and "multi_krum" share a kernel (multi=1 vs multi=m) but keep distinct
# codes so sweep tables name the defense family they ran.
DEFENSE_CODES = {
    "floa": 0,
    "mean": 1,
    "median": 2,
    "trimmed_mean": 3,
    "krum": 4,
    "multi_krum": 5,
    "geometric_median": 6,
}
_FLOA_CODE = 0


@dataclasses.dataclass(frozen=True)
class DefenseSpec:
    """Per-lane aggregation rule: analog FLOA (name="floa") or a digital
    screening defense with its hyper-parameters.

    This is the validation layer for the defense kernels: trim / Krum bounds
    are checked HERE, on concrete Python ints, because `assert`s on traced
    values silently vanish under jit (and a bare `assert 2 * trim < u` says
    nothing useful about a negative trim anyway).

    gm_iters is a static Weiszfeld iteration count (a lax.scan length), so it
    cannot vary across the lanes of one compiled sweep — SweepSpec enforces
    that all geometric-median lanes agree.
    """

    name: str = "floa"
    trim: int = 1           # trimmed_mean: drop `trim` largest+smallest/coord
    num_byzantine: int = 0  # krum / multi_krum: assumed attacker count f
    multi: int = 1          # multi_krum: average the m best-scored workers
    gm_iters: int = 8       # geometric_median: Weiszfeld iterations

    @property
    def code(self) -> int:
        return DEFENSE_CODES[self.name]

    @property
    def is_digital(self) -> bool:
        return self.name != "floa"

    def validate(self, num_workers: int) -> "DefenseSpec":
        if self.name not in DEFENSE_CODES:
            raise ValueError(
                f"unknown defense {self.name!r}; one of {sorted(DEFENSE_CODES)}")
        u = num_workers
        if self.name == "trimmed_mean" and not 0 <= 2 * self.trim < u:
            raise ValueError(
                f"trimmed_mean trim={self.trim} invalid for U={u}: "
                f"need 0 <= 2*trim < U")
        if self.name in ("krum", "multi_krum"):
            if not 0 <= self.num_byzantine < u:
                raise ValueError(
                    f"krum num_byzantine={self.num_byzantine} invalid for "
                    f"U={u}: need 0 <= f < U")
            if not 1 <= self.multi <= u:
                raise ValueError(
                    f"krum multi={self.multi} invalid for U={u}: "
                    f"need 1 <= multi <= U")
        if self.name == "geometric_median" and self.gm_iters < 1:
            raise ValueError(f"geometric_median gm_iters={self.gm_iters} < 1")
        return self

    _KWARGS_BY_DEFENSE = {
        "trimmed_mean": frozenset({"trim"}),
        "krum": frozenset({"num_byzantine", "multi"}),
        "multi_krum": frozenset({"num_byzantine", "multi"}),
        "geometric_median": frozenset({"iters", "gm_iters"}),
    }

    @classmethod
    def from_kwargs(cls, name: str, **kw) -> "DefenseSpec":
        """Build from `FLTrainer`-style (defense, **defense_kwargs).

        Kwargs irrelevant to `name` are rejected, matching the pytree path
        (where e.g. coordinate_median(trim=...) is a TypeError) — silently
        dropping them would run a different defense than the caller asked
        for.
        """
        extra = set(kw) - cls._KWARGS_BY_DEFENSE.get(name, frozenset())
        if extra:
            raise ValueError(
                f"defense {name!r} does not accept kwargs {sorted(extra)}")
        fields = dict(trim=kw.get("trim", 1),
                      num_byzantine=kw.get("num_byzantine", 0),
                      multi=kw.get("multi", 1),
                      gm_iters=kw.get("iters", kw.get("gm_iters", 8)))
        if name == "krum" and fields["multi"] > 1:
            name = "multi_krum"
        return cls(name=name, **fields)


class ScenarioParams(NamedTuple):
    """One scenario's FLOA knobs as arrays (NamedTuple == pytree, so a list of
    these stacks with a single tree_map into the [S, ...] sweep axis)."""

    policy: Array      # int32 [] — POLICY_CODES
    attack: Array      # int32 [] — ATTACK_CODES
    byz_mask: Array    # bool  [U]
    sigma: Array       # f32   [U] Rayleigh scales
    p_max: Array       # f32   [U] per-worker max power
    dim: Array         # f32   []  power-accounting gradient dim D (eq. 4)
    noise_std: Array   # f32   []  receiver AWGN std (0 under EF)
    alpha: Array       # f32   []  raw learning rate (eq. 8)
    defense: Array     # int32 [] — DEFENSE_CODES (0 = analog FLOA combine)
    def_trim: Array    # int32 []  trimmed_mean trim count
    def_f: Array       # int32 []  (multi-)Krum assumed attacker count f
    def_multi: Array   # int32 []  multi-Krum average count m
    # Adaptive-adversary axis (PR 8); the numpy-scalar defaults keep older
    # direct constructions (tests, notebooks) valid and inert.  numpy (not
    # jnp) scalars: a jnp default would run a device computation at class
    # definition, and `jax.distributed.initialize` refuses to bootstrap
    # once any computation has executed — importing repro must stay free of
    # device work for the multi-host entry points to exist at all.
    chan_rho: Array = np.float32(0.0)    # f32 [] Gauss-Markov fading rho
    part_k: Array = np.int32(1 << 30)    # int32 [] K-of-U participation count
    #                                      (>= U means full participation)

    @property
    def num_workers(self) -> int:
        return self.byz_mask.shape[-1]


def from_floa(cfg, alpha: float,
              defense: Optional[DefenseSpec] = None,
              participants: Optional[int] = None) -> ScenarioParams:
    """FLOAConfig (frozen dataclass) -> traceable ScenarioParams.

    EF scenarios get noise_std forced to 0 here (the dataclass path simply
    never reaches the noise branch under EF; the branchless path always adds
    the noise term, so the std itself must be zero).

    defense: optional DefenseSpec; omitted means the analog FLOA combine.
    Digital lanes keep the full channel/power params (their branchless floa
    half still traces) but the lane's update consumes the screening defense
    output instead.

    participants: optional K for K-of-U per-round client sampling (the sweep
    engine draws the round's K participants from the lane key); None means
    full participation.  K = U is a valid — bitwise-pinned — degenerate case
    but still exercises the masked machinery, which is exactly what the
    K=U == full-participation contract tests.
    """
    cfg.validate()
    u = cfg.num_workers
    defense = (defense or DefenseSpec()).validate(u)
    if participants is not None and not 1 <= participants <= u:
        raise ValueError(
            f"participants={participants} invalid for U={u}: need 1 <= K <= U")
    mask = (jnp.asarray(cfg.attack.byzantine_mask, dtype=bool)
            if cfg.attack.byzantine_mask else jnp.zeros((u,), dtype=bool))
    is_ef = cfg.power.policy == Policy.EF
    return ScenarioParams(
        policy=jnp.int32(POLICY_CODES[cfg.power.policy]),
        attack=jnp.int32(ATTACK_CODES[cfg.attack.attack]),
        byz_mask=mask,
        sigma=cfg.channel.sigmas(),
        p_max=cfg.power.p_maxes(),
        dim=jnp.float32(cfg.power.dim),
        noise_std=jnp.float32(0.0 if is_ef else cfg.channel.noise_std),
        alpha=jnp.float32(alpha),
        defense=jnp.int32(defense.code),
        def_trim=jnp.int32(defense.trim),
        def_f=jnp.int32(defense.num_byzantine),
        def_multi=jnp.int32(defense.multi),
        chan_rho=jnp.float32(cfg.channel.markov_rho),
        part_k=jnp.int32(u if participants is None else participants),
    )


def stack(params: Tuple[ScenarioParams, ...]) -> ScenarioParams:
    """[ScenarioParams] * S -> ScenarioParams with a leading S axis on every
    leaf.  All scenarios must share U (shapes must match to stack)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params)


@dataclasses.dataclass(frozen=True)
class LaneGroups:
    """Static partition of a sweep's lane axis by defense code.

    Defense codes are concrete config (DefenseSpec / ScenarioParams.defense is
    filled from Python ints), so the partition is known at ENGINE BUILD time —
    the grouped dispatch in fl/sweep.py uses it to run each defense family's
    kernel once over a contiguous sub-slab instead of paying every family for
    every lane under a vmapped `lax.switch`.

    The execution order is shard-uniform: each group is ghost-padded to a
    multiple of `shards` (replicating its LAST member, the same trick as
    `pad_lanes`) and laid out device-major, so after a shard_map over
    ("data",) every device's local lane block has the IDENTICAL static group
    layout `local_slices` — grouped dispatch then works inside the one shared
    trace with purely static slicing.  shards=1 is the unsharded engine.

      codes         group defense codes, ascending (one entry per group)
      perm          [S_exec] execution row -> source lane index (ghost rows
                    repeat their group's last real lane)
      inverse       [S] source lane -> an execution row carrying its
                    trajectory (ghosts are replicas, any occurrence is valid)
      local_slices  ((code, start, end), ...) group boundaries in LOCAL
                    (per-shard) lane coordinates
      shards        device count the layout was built for
    """

    codes: Tuple[int, ...]
    perm: Tuple[int, ...]
    inverse: Tuple[int, ...]
    local_slices: Tuple[Tuple[int, int, int], ...]
    shards: int

    @property
    def exec_lanes(self) -> int:
        return len(self.perm)

    @property
    def lanes_per_shard(self) -> int:
        return len(self.perm) // self.shards

    @property
    def num_ghosts(self) -> int:
        return len(self.perm) - len(self.inverse)


def build_lane_groups(codes, shards: int = 1) -> LaneGroups:
    """Lane defense codes (concrete ints, lane order) -> LaneGroups.

    Within a group the original lane order is preserved (stable partition);
    groups are ordered by ascending code so the analog FLOA group (code 0),
    when present, is always the first slice.
    """
    codes = [int(c) for c in codes]
    assert codes, "empty lane-code list"
    assert shards >= 1, shards
    group_codes = sorted(set(codes))
    padded = {}
    for c in group_codes:
        members = [i for i, ci in enumerate(codes) if ci == c]
        members += [members[-1]] * (-len(members) % shards)
        padded[c] = members
    per_shard = {c: len(padded[c]) // shards for c in group_codes}
    perm = []
    for d in range(shards):
        for c in group_codes:
            k = per_shard[c]
            perm.extend(padded[c][d * k:(d + 1) * k])
    first_row = {}
    for row, lane in enumerate(perm):
        first_row.setdefault(lane, row)
    local_slices, off = [], 0
    for c in group_codes:
        local_slices.append((c, off, off + per_shard[c]))
        off += per_shard[c]
    return LaneGroups(
        codes=tuple(group_codes), perm=tuple(perm),
        inverse=tuple(first_row[i] for i in range(len(codes))),
        local_slices=tuple(local_slices), shards=shards)


def permute_lanes(sp, perm):
    """Gather a lane-stacked pytree (ScenarioParams, key array, flat [S, D]
    state, ...) into LaneGroups execution order.  `perm` may repeat source
    lanes (per-group ghost padding), so this subsumes `pad_lanes` for the
    grouped engine: ghosts replicate a real lane of the SAME defense family
    and run a real, discarded scenario."""
    idx = jnp.asarray(perm, dtype=jnp.int32)
    return jax.tree_util.tree_map(lambda x: x[idx], sp)


def pad_lanes(sp, total: int):
    """Pad a lane-stacked pytree (ScenarioParams, key array, flat [S, D]
    state, ...) to `total` lanes by replicating the last real lane.  The
    single definition of ghost-lane padding for mesh sharding: every leaf
    keeps valid values, so the padded lanes run real — discarded —
    scenarios instead of NaNs poisoning collective-free lane math."""
    s = jax.tree_util.tree_leaves(sp)[0].shape[0]
    assert total >= s, (total, s)
    if total == s:
        return sp
    return jax.tree_util.tree_map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[-1:], (total - s,) + x.shape[1:])]), sp)


def sample_gains(key: Array, sp: ScenarioParams) -> Array:
    """|h_{i,t}| ~ Rayleigh(sp.sigma), [U] — channel.sample_channel_gains
    with the scales coming from the traceable params (both share
    channel.rayleigh_gains, so the draws are identical per key).  Under EF
    the dataclass path forces h == 1; scenario_coefficients handles that
    branchlessly, so the raw draw here is simply ignored for EF scenarios."""
    return rayleigh_gains(key, sp.sigma)


def participation_mask(key: Array, part_k: Array, num_workers: int) -> Array:
    """K-of-U per-round client sampling: the K workers with the smallest
    uniform scores participate (rank-of-rank top-K, so exactly K of U and
    every subset is equally likely).  part_k may be traced; part_k >= U is
    an all-True mask (full participation)."""
    scores = jax.random.uniform(key, (num_workers,))
    rank = jnp.argsort(jnp.argsort(scores))
    return rank < part_k


def scenario_coefficients(
    h_abs: Array, sp: ScenarioParams, gbar: Array, eps2: Array,
    part: Optional[Array] = None,
) -> Tuple[Array, Array, Array, Array, Array]:
    """Branchless eq. (7) coefficient derivation for one scenario.

    Returns (s, bias_w, jam_std, noise_std, dir_w):
      s [U]       signed per-worker payload coefficients (attacks.py semantics)
      bias_w []   de-standardization bias weight (x gbar x 1)
      jam_std []  GAUSSIAN jamming noise std (0 unless that attack is active)
      noise_std []  effective receiver AWGN std (0 under EF)
      dir_w []    received weight of the COLLUDING/OMNISCIENT cohort's shared
                  rank-1 direction (0 for every other attack; the caller owns
                  the direction row itself — see fl/sweep.py)

    part: optional [U] bool participation mask (`participation_mask`); None
    is full participation with zero masking ops traced, and an all-True mask
    is bitwise-identical to None (the K=U contract).

    Every policy/attack formula is computed, then selected with jnp.where on
    the int32 codes — so the whole thing vmaps over a stacked scenario axis.
    The selected values are the *same expressions* the branching modules
    compute, so per-scenario outputs match attacks.signed_coefficients /
    power_control.transmit_amplitudes bit-for-bit.
    """
    u = sp.byz_mask.shape[-1]
    dim = sp.dim   # power-accounting D from the config, NOT the model's size
    is_ef = sp.policy == _EF
    mask = sp.byz_mask
    # Non-participants transmit nothing: they drop out of the payload, the
    # bias/jamming/directional cohort sums, and the EF mean share.
    eff_mask = mask if part is None else (mask & part)
    eps = jnp.sqrt(eps2)

    # --- power_control.transmit_amplitudes, all policies at once (the
    # formulas live in power_control/attacks as array helpers so the
    # branching and branchless paths cannot drift apart).
    b0 = ci_b0_arrays(sp.p_max, sp.sigma, dim)
    ci_amp = b0 / h_abs
    bev_amp = max_amplitude_arrays(sp.p_max, dim)
    amp = jnp.where(sp.policy == _CI, ci_amp,
                    jnp.where(sp.policy == _TCI,
                              jnp.minimum(ci_amp, bev_amp), bev_amp))
    if part is None:
        ef_share = 1.0 / u
    else:
        # (1/u) * (u/K): == 1.0/u bitwise at the full mask (the scale is
        # exactly 1.0), the 1/K mean share otherwise.
        ef_share = (1.0 / u) * (u / jnp.sum(part.astype(jnp.float32)))
    honest_s = jnp.where(is_ef, ef_share, amp * h_abs)

    # --- attacks.signed_coefficients (+ the EF early-return's sign flip).
    phat = A.strongest_attack_amplitude(sp.p_max, dim, gbar, eps2)
    strongest_s = -eps * phat * h_abs
    attacker_s = jnp.where(sp.attack == _STRONGEST, strongest_s,
                           jnp.where(sp.attack == _SIGN_FLIP, -honest_s, 0.0))
    # EF models any active attacker as a sign-flipped mean share (-1/U).
    attacker_s = jnp.where(is_ef, -honest_s, attacker_s)
    active = sp.attack != _NONE
    s = jnp.where(active & mask, attacker_s, honest_s)
    if part is not None:
        s = jnp.where(part, s, 0.0)

    # PS de-standardizes assuming protocol power for every worker; attackers
    # that never standardized (STRONGEST/GAUSSIAN/COLLUDING/OMNISCIENT)
    # leave the bias behind.
    has_bias = active & (~is_ef) & ((sp.attack == _STRONGEST)
                                    | (sp.attack == _GAUSSIAN)
                                    | (sp.attack == _COLLUDING)
                                    | (sp.attack == _OMNISCIENT))
    bias_w = jnp.where(has_bias,
                       jnp.sum(jnp.where(eff_mask, honest_s, 0.0)), 0.0)

    # --- attacks.gaussian_jam_std.
    jam = A.jam_std_arrays(h_abs, sp.p_max, dim, eff_mask, eps2)
    jam_std = jnp.where(active & (~is_ef) & (sp.attack == _GAUSSIAN), jam, 0.0)

    # --- adaptive rank-1 attacks: the cohort's shared-direction weight
    # (attacks.colluding_dir_weight / omniscient_dir_weight; unused outputs
    # are dead code XLA drops when no directional lane is present).
    collude_w = A.colluding_dir_weight(h_abs, sp.p_max, dim, eff_mask, eps2)
    omni_w = A.omniscient_dir_weight(h_abs, sp.p_max, dim, eff_mask,
                                     gbar, eps2)
    directional = active & (~is_ef)
    dir_w = jnp.where(directional & (sp.attack == _COLLUDING), collude_w,
                      jnp.where(directional & (sp.attack == _OMNISCIENT),
                                omni_w, 0.0))

    noise_std = jnp.where(is_ef, 0.0, sp.noise_std)
    return s, bias_w, jam_std, noise_std, dir_w
