"""FLOA gradient aggregation — the paper's eq. (6)-(8) as a JAX transform.

TPU-native realization of over-the-air computation (see DESIGN.md §2): the
wireless MAC's superposition IS a weighted reduction over the worker axis, so
on a ("data","model") mesh the whole pipeline lowers to

    per-worker grads  g[U, ...]   (U sharded on "data" via vmap(grad))
    round stats       gbar, eps2  (two scalar all-reduces — the side channel)
    channel + power   s[U]        (replicated scalars)
    OTA superposition sum_i s_i g_i   ==  one all-reduce over "data"
    de-standardize    + bias_w * gbar * 1
    receiver noise    + eps * z,  z ~ N(0, z^2)  (sharded draw)

`aggregate` is pure and jit-safe; the FL trainer and every architecture's
train_step call it as a drop-in replacement for the plain gradient mean.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as A
from repro.core import standardize as S
from repro.core.channel import ChannelConfig, sample_channel_gains
from repro.core.power_control import Policy, PowerConfig, received_coefficients

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FLOAConfig:
    """Everything needed to simulate one FLOA round."""

    channel: ChannelConfig
    power: PowerConfig
    attack: A.AttackConfig = dataclasses.field(
        default_factory=lambda: A.AttackConfig()
    )

    @property
    def num_workers(self) -> int:
        return self.channel.num_workers

    def validate(self) -> "FLOAConfig":
        assert self.channel.num_workers == self.power.num_workers
        if self.attack.byzantine_mask:
            assert len(self.attack.byzantine_mask) == self.channel.num_workers
        return self


def per_worker_grads(
    loss_fn: Callable,
    params,
    batch,
    num_workers: int,
    has_aux: bool = False,
):
    """Per-worker gradients via vmap(grad) over a worker-split batch.

    batch leaves are split [global_B, ...] -> [U, B/U, ...]; the leading U axis
    is what gets sharded over the "data" mesh axis, so each device computes its
    own worker's gradient only (FLOA's privacy property: raw per-worker
    gradients never leave their shard).
    Returns (grads_u, aux_u) with leading U axes.
    """
    def split(x):
        assert x.shape[0] % num_workers == 0, (
            f"global batch {x.shape[0]} not divisible by U={num_workers}"
        )
        return x.reshape(num_workers, x.shape[0] // num_workers, *x.shape[1:])

    worker_batch = jax.tree_util.tree_map(split, batch)
    gfn = jax.grad(loss_fn, has_aux=has_aux)
    if has_aux:
        grads_u, aux_u = jax.vmap(gfn, in_axes=(None, 0))(params, worker_batch)
        return grads_u, aux_u
    grads_u = jax.vmap(gfn, in_axes=(None, 0))(params, worker_batch)
    return grads_u, None


def _weighted_reduce(grads_u, weights: Array):
    """sum_i weights[i] * g_i over the leading worker axis (the OTA sum)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.tensordot(weights.astype(g.dtype), g, axes=(0, 0)), grads_u
    )


def _sharded_noise(key: Array, template, std) -> "jax.tree_util.PyTreeDef":
    """Pytree of N(0, std^2) draws matching `template`'s shapes/dtypes.

    Uses a distinct folded key per leaf; with jax_threefry_partitionable the
    draw is generated shard-locally (never materialized replicated).
    """
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    noise = [
        (std * jax.random.normal(k, x.shape, jnp.float32)).astype(x.dtype)
        for k, x in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, noise)


def aggregate(
    grads_u,
    key: Array,
    cfg: FLOAConfig,
) -> Tuple[object, dict]:
    """One FLOA round: per-worker grads [U, ...] -> noisy aggregate (eq. 7).

    Returns (gagg, aux) where aux carries the round's channel draw, received
    coefficients and stats (for logging / theory cross-checks).
    """
    cfg.validate()
    k_ch, k_z, k_jam = jax.random.split(key, 3)

    # --- standardization side-channel (workers report truthful scalar stats).
    gbar_i, eps2_i = S.per_worker_scalar_stats(grads_u)
    gbar, eps2 = S.global_stats(gbar_i, eps2_i)

    if cfg.power.policy == Policy.EF:
        # Error-free benchmark: perfect aggregation (h=1, z=0). Attackers (if
        # any) contribute a sign-flipped mean share — the digital analogue.
        u = cfg.num_workers
        sign = jnp.where(cfg.attack.mask(), -1.0, 1.0) if cfg.attack.byzantine_mask else jnp.ones((u,))
        if cfg.attack.attack == A.AttackType.NONE:
            sign = jnp.ones((u,))
        s = sign / u
        gagg = _weighted_reduce(grads_u, s)
        aux = dict(h_abs=jnp.ones((u,)), coeffs=s, gbar=gbar, eps2=eps2,
                   bias_w=jnp.zeros(()))
        return gagg, aux

    # --- channel draw + per-worker signed coefficients (honest & Byzantine).
    h_abs = sample_channel_gains(k_ch, cfg.channel)
    s, bias_w = A.signed_coefficients(
        h_abs, cfg.power, cfg.channel, cfg.attack, gbar, eps2
    )

    # --- OTA superposition == all-reduce over the "data" axis.
    gagg = _weighted_reduce(grads_u, s)

    # --- de-standardization bias from attackers (eq. 7 third term).
    gagg = jax.tree_util.tree_map(
        lambda g: g + (bias_w * gbar).astype(g.dtype), gagg
    )

    # --- receiver AWGN, scaled by eps_t (eq. 7 fourth term).
    eps = jnp.sqrt(eps2)
    if cfg.channel.noise_std > 0.0:
        z = _sharded_noise(k_z, gagg, cfg.channel.noise_std)
        gagg = jax.tree_util.tree_map(lambda g, n: g + eps.astype(g.dtype) * n, gagg, z)

    # --- unstructured jamming (GAUSSIAN ablation only; 0 otherwise).
    jam_std = A.gaussian_jam_std(h_abs, cfg.power, cfg.attack, eps2)
    if cfg.attack.attack == A.AttackType.GAUSSIAN and cfg.attack.num_attackers:
        jam = _sharded_noise(k_jam, gagg, 1.0)
        gagg = jax.tree_util.tree_map(
            lambda g, n: g + jam_std.astype(g.dtype) * n, gagg, jam
        )

    aux = dict(h_abs=h_abs, coeffs=s, gbar=gbar, eps2=eps2, bias_w=bias_w)
    return gagg, aux


# Below this flat size the einsum oracle beats the kernel's grid overhead;
# above it (and on TPU, where the kernel compiles to Mosaic rather than the
# interpreter) the fused single-pass kernel wins — it is bandwidth-bound.
BATCHED_KERNEL_MIN_D = 1 << 16


def flatten_worker_grads(grads_u, batch_dims: int = 1):
    """Pytree with [*lead, ...] leaves -> ([*lead, D] matrix, unflatten fn).

    batch_dims counts the leading axes shared by every leaf ([U] for a single
    scenario, [S, U] for a stacked sweep).  unflatten maps a [*lead[:-1], D]
    aggregate (the worker axis reduced away) back to the parameter pytree.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads_u)
    lead = leaves[0].shape[:batch_dims]
    lead_n = 1
    for n in lead:
        lead_n *= int(n)
    sizes = [int(x.size) // lead_n for x in leaves]
    shapes = [x.shape[batch_dims:] for x in leaves]
    flat = jnp.concatenate(
        [x.reshape(*lead, -1).astype(jnp.float32) for x in leaves], axis=-1
    )

    def unflatten(vec):
        out, off = [], 0
        out_lead = vec.shape[:-1]
        for n, shp, x in zip(sizes, shapes, leaves):
            out.append(vec[..., off:off + n].reshape(*out_lead, *shp)
                       .astype(x.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten


def batched_floa_combine(
    coeffs: Array,
    flat: Array,
    noise: Array,
    bias: Array,
    eps: Array,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Array:
    """[S, U, D] OTA combine: out[s] = coeffs[s] @ flat[s] + bias[s] + eps[s] z[s].

    The sweep engine's hot spot.  Routed through the fused Pallas kernel when
    the flattened gradient is large and the backend compiles it natively
    (TPU); the einsum reference otherwise — on CPU hosts the kernel only runs
    in interpret mode, which is for correctness tests, not speed.
    """
    if use_kernel is None:
        use_kernel = (jax.default_backend() == "tpu"
                      and flat.shape[-1] >= BATCHED_KERNEL_MIN_D)
    if use_kernel:
        from repro.kernels import ops
        return ops.floa_aggregate_batched(coeffs, flat, noise, bias, eps,
                                          interpret=interpret)
    from repro.kernels import ref
    return ref.floa_aggregate_batched_ref(coeffs, flat, noise, bias, eps)


def batched_floa_step(
    w: Array,
    alpha: Array,
    coeffs: Array,
    flat: Array,
    noise: Array,
    bias: Array,
    eps: Array,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """Fused [S, U, D] OTA combine + PS update (eq. 7 + eq. 8), flat state.

        gagg[s]  = coeffs[s] @ flat[s] + bias[s] + eps[s] * noise[s]
        w_new[s] = w[s] - alpha[s] * gagg[s]

    Returns (w_new, gagg); gagg is materialized so the sweep engine can log
    grad norms without re-deriving it from the update.  Same TPU-kernel /
    einsum-oracle routing and oracle-equivalence contract as
    `batched_floa_combine` — on TPU with a large flat gradient the whole
    round update is one pass over the [S, U, D] slab.
    """
    if use_kernel is None:
        use_kernel = (jax.default_backend() == "tpu"
                      and flat.shape[-1] >= BATCHED_KERNEL_MIN_D)
    if use_kernel:
        from repro.kernels import ops
        return ops.floa_step_batched(w, coeffs, flat, noise, bias, eps,
                                     alpha, interpret=interpret)
    from repro.kernels import ref
    return ref.floa_step_batched_ref(w, coeffs, flat, noise, bias, eps, alpha)


def mean_aggregate(grads_u) -> object:
    """Plain FedSGD mean (the EF path without the FLOA bookkeeping)."""
    return jax.tree_util.tree_map(lambda g: jnp.mean(g, axis=0), grads_u)


def floa_grad(
    loss_fn: Callable,
    params,
    batch,
    key: Array,
    cfg: FLOAConfig,
    has_aux: bool = False,
):
    """Convenience: per-worker grads + FLOA aggregation in one call.

    Returns (gagg, aux) — aux includes per-worker loss-fn aux if has_aux.
    """
    grads_u, fn_aux = per_worker_grads(
        loss_fn, params, batch, cfg.num_workers, has_aux=has_aux
    )
    gagg, aux = aggregate(grads_u, key, cfg)
    if fn_aux is not None:
        aux["loss_aux"] = fn_aux
    return gagg, aux
