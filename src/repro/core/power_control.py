"""Power-control policies for FLOA transmitters (paper §II-B.1/2).

Every policy maps (channel gains |h| [U], config) -> transmit amplitudes p [U]
subject to the per-worker constraint  D p_i^2 <= p_i^max   (paper eq. 4).

CI  (channel inversion, eq. 10):  p_i = b0 / |h_i| with
    b0^2 = P0_max * lambda,  P0_max = min_i p_i^max / D,
    lambda = E[min_i |h_i|^2] = 1 / sum_i (1/(2 sigma_i^2)).
    The received coefficient p_i |h_i| == b0 for every worker (amplitude
    alignment), which is why CI approximates the error-free case when benign
    (Lemma 1) but hands a fixed, small voting weight to honest workers under
    attack (Thm 2, Remark 1).

BEV (best-effort voting, eq. 11):  p_i = sqrt(p_i^max / D), CSI-independent.
    Honest workers shout at max power; received coefficient p_i|h_i| scales
    with the channel draw, E[p_i|h_i|] = sqrt(pi p_i^max / (2D)) sigma_i.

EF  (error-free benchmark, §IV-A): h == 1, z == 0, aggregate = mean of local
    gradients — the ideal FedSGD baseline the paper compares against.

TRUNCATED_CI (beyond paper): real radios cannot exceed p_max instantaneously;
    p_i = min(b0/|h_i|, sqrt(p_i^max/D)).  The paper's b0 satisfies eq. (4)
    only in expectation; this variant enforces it per draw.  Exposed for
    ablations, not used in the paper-faithful reproduction path.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Union

import jax
import jax.numpy as jnp

from repro.core.channel import ChannelConfig, min_sq_gain_from_sigmas

Array = jax.Array


class Policy(str, enum.Enum):
    CI = "ci"
    BEV = "bev"
    EF = "ef"
    TRUNCATED_CI = "truncated_ci"


@dataclasses.dataclass(frozen=True)
class PowerConfig:
    """p_max: per-worker max transmit power (scalar or [U]); dim: gradient dim D."""

    num_workers: int
    dim: int
    p_max: Union[float, tuple] = 1.0
    policy: Policy = Policy.BEV

    def p_maxes(self) -> Array:
        p = jnp.asarray(self.p_max, dtype=jnp.float32)
        return jnp.broadcast_to(p, (self.num_workers,))


def ci_b0_arrays(p_maxes: Array, sigmas: Array, dim) -> Array:
    """b0 = sqrt(P0_max * lambda) from raw arrays — the one CI power formula,
    shared by the dataclass path below and the traceable sweep path
    (core.scenario.scenario_coefficients); `dim` may be a scalar or traced."""
    p0_max = jnp.min(p_maxes) / dim
    return jnp.sqrt(p0_max * min_sq_gain_from_sigmas(sigmas))


def ci_b0(power: PowerConfig, channel: ChannelConfig) -> Array:
    """b0 = sqrt(P0_max * lambda), the common received amplitude under CI."""
    return ci_b0_arrays(power.p_maxes(), channel.sigmas(), float(power.dim))


def max_amplitude_arrays(p_maxes: Array, dim) -> Array:
    """sqrt(p_i^max / D) from raw arrays (shared with core.scenario)."""
    return jnp.sqrt(p_maxes / dim)


def max_amplitude(power: PowerConfig) -> Array:
    """sqrt(p_i^max / D): the BEV amplitude and the per-draw cap, [U]."""
    return max_amplitude_arrays(power.p_maxes(), float(power.dim))


def transmit_amplitudes(
    h_abs: Array, power: PowerConfig, channel: ChannelConfig
) -> Array:
    """Per-worker transmit amplitude p_i for this round's channel draw.  [U]."""
    if power.policy == Policy.CI:
        return ci_b0(power, channel) / h_abs
    if power.policy == Policy.TRUNCATED_CI:
        return jnp.minimum(ci_b0(power, channel) / h_abs, max_amplitude(power))
    if power.policy == Policy.BEV:
        return jnp.broadcast_to(max_amplitude(power), h_abs.shape)
    if power.policy == Policy.EF:
        # Error-free: the aggregate is the plain mean; model it as p_i|h_i| = 1/U
        # with h forced to 1 by the caller.
        return jnp.full_like(h_abs, 1.0 / power.num_workers)
    raise ValueError(f"unknown policy {power.policy}")


def received_coefficients(
    h_abs: Array, power: PowerConfig, channel: ChannelConfig
) -> Array:
    """s_i = p_i |h_i|: the per-worker weight the MAC applies to worker i."""
    if power.policy == Policy.EF:
        return jnp.full_like(h_abs, 1.0 / power.num_workers)
    return transmit_amplitudes(h_abs, power, channel) * h_abs
