"""Digital-FL Byzantine-robust aggregation baselines (paper §I related work).

The paper's motivation: screening defenses (median/Krum/...) need *individual*
local gradients, which analog aggregation hides — so they cannot be applied to
FLOA.  We implement them anyway for the *digital* comparison mode (per-worker
gradients explicitly gathered), so experiments can quantify the robustness /
communication-cost trade-off the paper argues about:

  coordinate-wise median           [Yin et al. 2018]
  coordinate-wise trimmed mean     [Yin et al. 2018]
  Krum / Multi-Krum                [Blanchard et al. 2017]
  geometric median (Weiszfeld)     [Minsker 2015 / RFA]

The matrix-native `flat_*` kernels are the single implementation: they map one
[U, D] per-worker gradient slab to a [D] aggregate, take their hyper-params
(trim, f, multi) as TRACED scalars so one trace serves every lane of a sweep
(masked sorted-prefix reductions instead of Python slicing), and are what the
sweep engine's defense-code lane axis dispatches over (`DEFENSE_CODES` in
core/scenario.py, `make_flat_defense_selector` below).  Hyper-param bounds are
validated in the config layer (`scenario.DefenseSpec.validate`) because
`assert`s on traced values vanish under jit; the kernels only re-check
concrete Python ints.

The pytree API (`digital_aggregate` and the named wrappers) flattens to the
slab, runs the flat kernel, and unravels — the legacy entry point the digital
`FLTrainer` uses.

NOTE: in digital mode the [U, ...] stack must be gathered (an all-gather over
"data" instead of FLOA's all-reduce) — exactly the communication overhead the
paper's analog scheme avoids; the roofline benchmarks expose the difference.
"""
from __future__ import annotations

import functools
import logging
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scenario import DEFENSE_CODES

Array = jax.Array

logger = logging.getLogger(__name__)

# Defense families by data layout.  Column-wise defenses reduce each of the
# D coordinates independently over the worker axis, so under a ("model",)-
# sharded sweep they run unchanged on each shard's local column block; the
# row-geometry defenses (Krum / multi-Krum / geometric median) score whole
# [D]-rows by pairwise distance and need the full rows gathered first
# (fl/sweep.py routes on this split).
COLUMNWISE_CODES = frozenset(
    DEFENSE_CODES[n] for n in ("mean", "median", "trimmed_mean"))
ROW_GEOMETRY_CODES = frozenset(
    DEFENSE_CODES[n] for n in ("krum", "multi_krum", "geometric_median"))


def _flatten_u(grads_u):
    """[U, ...] pytree -> ([U, D] matrix, unravel fn)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_u)
    u = leaves[0].shape[0]
    flat = jnp.concatenate([x.reshape(u, -1).astype(jnp.float32) for x in leaves], axis=1)

    def unravel(vec):
        out, off = [], 0
        for x in leaves:
            n = int(x.size) // u
            out.append(vec[off : off + n].reshape(x.shape[1:]).astype(x.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unravel


# --------------------------------------------------------- flat [U, D] kernels

# Below this flat size (or off-TPU, where Pallas only interprets) jnp.sort's
# generic lowering is fine; above it the sorting-network kernels
# (kernels/defense_sort.py) sort the [U, TILE] block in one VMEM pass.
SORT_KERNEL_MIN_D = 1 << 14
# Worker-axis routing: up to this U the fully-unrolled odd-even network is
# the kernel (O(U^2) min/max pairs is cheap when U is tiny); above it the
# unrolled trace explodes quadratically, so large-U slabs take the bitonic
# stage kernel (O(log^2 U) whole-block ops, U padded to a power of two) up
# to its own VMEM ceiling, and the jnp.sort oracle beyond that.
SORT_UNROLL_MAX_U = 32


def sorted_columns(flat: Array, use_kernel: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> Array:
    """Ascending per-coordinate sort over the worker axis — the screening
    primitive coordinate-median and trimmed-mean share.  Routed to a Pallas
    sorting-network kernel on TPU at large D (same routing contract as
    `core.aggregation.batched_floa_combine`), `jnp.sort` elsewhere.

    The worker axis picks the kernel: U <= SORT_UNROLL_MAX_U takes the
    unrolled odd-even network, larger U the bitonic stage kernel (while its
    padded U fits VMEM).  The guard is unconditional — even with
    use_kernel=True a large-U slab is NEVER routed into the unrolled
    network, whose O(U^2) trace at U >= 1k would dwarf the sort itself;
    above BITONIC_MAX_U (padded) no VMEM-resident column block exists
    either, so the router falls back to `jnp.sort` explicitly and logs
    once (it used to fall through silently — ROADMAP bug)."""
    u = flat.shape[0]
    if use_kernel is None:
        use_kernel = (jax.default_backend() == "tpu"
                      and flat.shape[-1] >= SORT_KERNEL_MIN_D)
    if use_kernel:
        from repro.kernels import ops
        if u <= SORT_UNROLL_MAX_U:
            return ops.sort_columns(flat, interpret=interpret)
        u_pad = 1 << max(u - 1, 0).bit_length()
        if u_pad <= ops.BITONIC_MAX_U:
            return ops.sort_columns_bitonic(flat, interpret=interpret)
        _log_sort_fallback_once(u, ops.BITONIC_MAX_U)
    return jnp.sort(flat, axis=0)


_sort_fallback_logged = False


def _log_sort_fallback_once(u: int, bitonic_max_u: int) -> None:
    """Explicit large-U fallback notice, emitted once per process: a kernel
    was requested (use_kernel resolved True) but U padded to a power of two
    exceeds the bitonic kernel's VMEM ceiling, so the sort takes `jnp.sort`'s
    generic lowering instead — correct, just not the Pallas path the caller
    asked for.  Logged (not warned): the test suite promotes warnings to
    errors, and this is routing telemetry, not a correctness hazard."""
    global _sort_fallback_logged
    if not _sort_fallback_logged:
        _sort_fallback_logged = True
        logger.warning(
            "sorted_columns: U=%d pads past BITONIC_MAX_U=%d — no "
            "VMEM-resident sorting-network kernel exists at this U, falling "
            "back to jnp.sort (XLA generic sort). Logged once per process.",
            u, bitonic_max_u)


def flat_mean(flat: Array) -> Array:
    return jnp.mean(flat, axis=0)


def flat_median(flat: Array) -> Array:
    # (srt[(u-1)//2] + srt[u//2]) / 2 == jnp.median: the middle element for
    # odd U ((x + x) / 2 is exact), the two-middle average for even U.
    u = flat.shape[0]
    srt = sorted_columns(flat)
    return (srt[(u - 1) // 2] + srt[u // 2]) / 2


def flat_trimmed_mean(flat: Array, trim) -> Array:
    """Drop the `trim` largest and smallest per coordinate, then mean.

    trim may be a traced int32 scalar (sweep lanes): the sorted column is
    reduced under an index mask instead of a Python slice, so the same trace
    serves every lane.  Concrete ints are range-checked here; traced values
    are the config layer's job (`DefenseSpec.validate`).
    """
    u = flat.shape[0]
    if isinstance(trim, (int, np.integer)) and not 0 <= 2 * int(trim) < u:
        raise ValueError(
            f"trimmed_mean trim={trim} invalid for U={u}: need 0 <= 2*trim < U")
    srt = sorted_columns(flat)
    idx = jnp.arange(u)
    keep = (idx >= trim) & (idx < u - trim)
    kept = jnp.sum(jnp.where(keep[:, None], srt, 0.0), axis=0)
    return kept / (u - 2 * trim)


def _krum_scores(flat: Array, num_byzantine) -> Array:
    """score_i = sum of the max(U-f-2, 1) smallest sq-distances to others.

    Exposed for the property-test suite (permutation equivariance of the
    scores is checkable even when near-ties make the selection itself
    fp-fragile).

    The broadcast difference materializes a [U, U, D] intermediate before
    XLA fuses — fine at the paper's U=10, catastrophic at U >= 1k (17 TB at
    U=4096, D=256) — so this is the SMALL-U path only; `flat_krum` routes
    U >= KRUM_BLOCK_MIN_U to `_krum_scores_blocked`.
    """
    u = flat.shape[0]
    closest = jnp.maximum(u - num_byzantine - 2, 1)
    d2 = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)  # [U,U]
    # Exclude self via a boolean mask: the seed's `d2 + eye * inf` poisoned
    # every OFF-diagonal entry with 0*inf = NaN, collapsing all scores to NaN
    # (and Krum to "always pick worker 0").  Pinned by the property suite.
    d2 = jnp.where(jnp.eye(u, dtype=bool), jnp.inf, d2)
    srt = jnp.sort(d2, axis=1)  # self-distance inf lands in the final column
    # closest <= U-2, so the masked prefix never touches the inf column.
    j = jnp.arange(u)
    return jnp.sum(jnp.where(j[None, :] < closest, srt, 0.0), axis=1)


# Above this U, Krum switches to the row-blocked distance path: the full
# [U, U] matrix (let alone the [U, U, D] broadcast intermediate) never
# materializes at once — only one [KRUM_BLOCK_ROWS, U] block at a time.
KRUM_BLOCK_MIN_U = 64
KRUM_BLOCK_ROWS = 128


def _krum_scores_blocked(flat: Array, num_byzantine,
                         block_rows: int = KRUM_BLOCK_ROWS) -> Array:
    """`_krum_scores` for large U, one [B, U] distance block at a time.

    Per block of B rows: d2 = |x_b|^2 + |x|^2 - 2 x_b x^T via a [B, D] x
    [D, U] matmul (clamped at 0 — the expanded form can go slightly
    negative in fp), self-distances masked to +inf by global row id, each
    row sorted and masked-prefix-reduced exactly like the small-U path.
    `lax.map` sequences the blocks, so peak memory is O(B*U + U*D), never
    O(U^2).  The expanded distance form differs from the direct (x-y)^2 sum
    at fp rounding level, so blocked vs small-U scores agree to rtol, not
    bitwise — the oracle-contract tests pin it.
    """
    u, d = flat.shape
    closest = jnp.maximum(u - num_byzantine - 2, 1)
    nb = -(-u // block_rows)
    pad = nb * block_rows - u
    fpad = jnp.pad(flat, ((0, pad), (0, 0)))
    sq = jnp.sum(jnp.square(flat), axis=1)                   # [U]
    sq_pad = jnp.pad(sq, (0, pad))
    blocks = fpad.reshape(nb, block_rows, d)
    sq_blocks = sq_pad.reshape(nb, block_rows)
    ids = jnp.arange(nb * block_rows).reshape(nb, block_rows)
    j = jnp.arange(u)

    def score_block(args):
        xb, sb, rb = args
        d2 = sb[:, None] + sq[None, :] - 2.0 * (xb @ flat.T)  # [B, U]
        d2 = jnp.maximum(d2, 0.0)
        d2 = jnp.where(rb[:, None] == j[None, :], jnp.inf, d2)
        srt = jnp.sort(d2, axis=1)
        return jnp.sum(jnp.where(j[None, :] < closest, srt, 0.0), axis=1)

    scores = jax.lax.map(score_block, (blocks, sq_blocks, ids))  # [nb, B]
    return scores.reshape(-1)[:u]


def flat_krum(flat: Array, num_byzantine, multi=1) -> Array:
    """(Multi-)Krum: average the `multi` lowest-scoring workers' gradients.
    num_byzantine and multi may be traced scalars (masked rank selection).
    Large worker populations take the blocked distance path (the [U, U]
    matrix never materializes at once)."""
    u = flat.shape[0]
    scores = (_krum_scores_blocked(flat, num_byzantine)
              if u >= KRUM_BLOCK_MIN_U
              else _krum_scores(flat, num_byzantine))
    ranked = flat[jnp.argsort(scores)]                 # [U, D], best first
    keep = jnp.arange(u) < multi
    sel = jnp.sum(jnp.where(keep[:, None], ranked, 0.0), axis=0)
    return sel / jnp.asarray(multi, flat.dtype)


def flat_geometric_median(flat: Array, iters: int = 8,
                          eps: float = 1e-8) -> Array:
    """Weiszfeld iterations for the geometric median (iters is static — a
    lax.scan length)."""

    def body(z, _):
        w = 1.0 / jnp.maximum(jnp.linalg.norm(flat - z, axis=1), eps)  # [U]
        z = jnp.sum(w[:, None] * flat, axis=0) / jnp.sum(w)
        return z, None

    z0 = jnp.mean(flat, axis=0)
    z, _ = jax.lax.scan(body, z0, None, length=iters)
    return z


# ------------------------------------- masked (partial-participation) kernels
#
# K-of-U client sampling (fl/sweep.py): non-participating workers never report
# a gradient, so every screening defense must run on the participating rows
# only.  Each masked kernel reduces BITWISE to its unmasked twin at a full
# mask (the K=U == full-participation sweep contract): selects with an
# all-True mask are identity, counts equal the static U, and means are
# rescaled by exactly-1.0 (mean * (U/count)) instead of re-divided — a
# sum/traced-count spelling would round differently from jnp.mean under jit
# (XLA strength-reduces the divide-by-constant into a reciprocal multiply).


def flat_masked_mean(flat: Array, mask: Array) -> Array:
    """Mean of the participating rows (== flat_mean at a full mask)."""
    u = flat.shape[0]
    scale = u / jnp.sum(mask.astype(flat.dtype))
    return jnp.mean(jnp.where(mask[:, None], flat, 0.0), axis=0) * scale


def flat_masked_median(flat: Array, mask: Array) -> Array:
    """Coordinate median over the participating rows: non-participants are
    +inf-padded so the sort pushes them past the end, and the two middle
    indices come from the traced participant count."""
    srt = sorted_columns(jnp.where(mask[:, None], flat, jnp.inf))
    cnt = jnp.sum(mask.astype(jnp.int32))
    return (srt[(cnt - 1) // 2] + srt[cnt // 2]) / 2


def flat_masked_trimmed_mean(flat: Array, trim, mask: Array) -> Array:
    """Trimmed mean over the participating rows: drop the `trim` largest and
    smallest PARTICIPATING values per coordinate, mean the rest."""
    u = flat.shape[0]
    srt = sorted_columns(jnp.where(mask[:, None], flat, jnp.inf))
    cnt = jnp.sum(mask.astype(jnp.int32))
    idx = jnp.arange(u)
    keep = (idx >= trim) & (idx < cnt - trim)
    kept = jnp.sum(jnp.where(keep[:, None], srt, 0.0), axis=0)
    return kept / (cnt - 2 * trim)


def _masked_krum_scores(flat: Array, num_byzantine, mask: Array) -> Array:
    """`_krum_scores` over the participating rows: distances to (or from) a
    non-participant are +inf, the closest-count comes from the participant
    count, and non-participant scores are +inf so ranking never picks them."""
    u = flat.shape[0]
    cnt = jnp.sum(mask.astype(jnp.int32))
    closest = jnp.maximum(cnt - num_byzantine - 2, 1)
    d2 = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    pair_ok = mask[:, None] & mask[None, :] & ~jnp.eye(u, dtype=bool)
    d2 = jnp.where(pair_ok, d2, jnp.inf)
    srt = jnp.sort(d2, axis=1)
    j = jnp.arange(u)
    scores = jnp.sum(jnp.where(j[None, :] < closest, srt, 0.0), axis=1)
    return jnp.where(mask, scores, jnp.inf)


def _masked_krum_scores_blocked(flat: Array, num_byzantine, mask: Array,
                                block_rows: int = KRUM_BLOCK_ROWS) -> Array:
    """`_krum_scores_blocked` with the participation mask applied per block
    (columns of non-participants +inf before the row sort, rows of
    non-participants +inf after)."""
    u, d = flat.shape
    cnt = jnp.sum(mask.astype(jnp.int32))
    closest = jnp.maximum(cnt - num_byzantine - 2, 1)
    nb = -(-u // block_rows)
    pad = nb * block_rows - u
    fpad = jnp.pad(flat, ((0, pad), (0, 0)))
    sq = jnp.sum(jnp.square(flat), axis=1)
    sq_pad = jnp.pad(sq, (0, pad))
    blocks = fpad.reshape(nb, block_rows, d)
    sq_blocks = sq_pad.reshape(nb, block_rows)
    ids = jnp.arange(nb * block_rows).reshape(nb, block_rows)
    j = jnp.arange(u)

    def score_block(args):
        xb, sb, rb = args
        d2 = sb[:, None] + sq[None, :] - 2.0 * (xb @ flat.T)
        d2 = jnp.maximum(d2, 0.0)
        d2 = jnp.where(rb[:, None] == j[None, :], jnp.inf, d2)
        d2 = jnp.where(mask[None, :], d2, jnp.inf)
        srt = jnp.sort(d2, axis=1)
        return jnp.sum(jnp.where(j[None, :] < closest, srt, 0.0), axis=1)

    scores = jax.lax.map(score_block, (blocks, sq_blocks, ids)).reshape(-1)[:u]
    return jnp.where(mask, scores, jnp.inf)


def flat_masked_krum(flat: Array, num_byzantine, multi, mask: Array) -> Array:
    """(Multi-)Krum over the participating rows (same large-U routing as
    `flat_krum`; non-participants score +inf, so `multi <= K` — enforced by
    the sweep spec validation — keeps them out of the averaged prefix)."""
    u = flat.shape[0]
    scores = (_masked_krum_scores_blocked(flat, num_byzantine, mask)
              if u >= KRUM_BLOCK_MIN_U
              else _masked_krum_scores(flat, num_byzantine, mask))
    ranked = flat[jnp.argsort(scores)]
    keep = jnp.arange(u) < multi
    sel = jnp.sum(jnp.where(keep[:, None], ranked, 0.0), axis=0)
    return sel / jnp.asarray(multi, flat.dtype)


def flat_masked_geometric_median(flat: Array, mask: Array, iters: int = 8,
                                 eps: float = 1e-8) -> Array:
    """Weiszfeld over the participating rows: non-participants get zero
    weight and the iteration starts from the participants' mean."""
    u = flat.shape[0]
    scale = u / jnp.sum(mask.astype(flat.dtype))

    def body(z, _):
        w = jnp.where(
            mask, 1.0 / jnp.maximum(jnp.linalg.norm(flat - z, axis=1), eps),
            0.0)
        z = jnp.sum(w[:, None] * flat, axis=0) / jnp.sum(w)
        return z, None

    z0 = jnp.mean(jnp.where(mask[:, None], flat, 0.0), axis=0) * scale
    z, _ = jax.lax.scan(body, z0, None, length=iters)
    return z


# ------------------------------------------------ branchless lane dispatch

# code -> flat kernel taking the uniform operand tuple (flat, trim, f, multi).
# Code 0 (analog FLOA) falls back to the mean: the sweep engine discards that
# branch's output for analog lanes (they take the OTA combine), but under a
# vmapped lax.switch every branch must still produce a [D] row.
_FLAT_KERNELS_BY_CODE: Dict[int, Callable] = {
    DEFENSE_CODES["floa"]: lambda op, it: flat_mean(op[0]),
    DEFENSE_CODES["mean"]: lambda op, it: flat_mean(op[0]),
    DEFENSE_CODES["median"]: lambda op, it: flat_median(op[0]),
    DEFENSE_CODES["trimmed_mean"]: lambda op, it: flat_trimmed_mean(op[0], op[1]),
    DEFENSE_CODES["krum"]: lambda op, it: flat_krum(op[0], op[2], op[3]),
    DEFENSE_CODES["multi_krum"]: lambda op, it: flat_krum(op[0], op[2], op[3]),
    DEFENSE_CODES["geometric_median"]:
        lambda op, it: flat_geometric_median(op[0], iters=it),
}

# Masked twins for K-of-U partial participation: uniform operand tuple
# (flat, trim, f, multi, mask).  The sweep engine selects this table at
# BUILD time only when the sweep contains participation lanes, so
# full-participation sweeps trace zero masking ops.
_MASKED_FLAT_KERNELS_BY_CODE: Dict[int, Callable] = {
    DEFENSE_CODES["floa"]: lambda op, it: flat_masked_mean(op[0], op[4]),
    DEFENSE_CODES["mean"]: lambda op, it: flat_masked_mean(op[0], op[4]),
    DEFENSE_CODES["median"]: lambda op, it: flat_masked_median(op[0], op[4]),
    DEFENSE_CODES["trimmed_mean"]:
        lambda op, it: flat_masked_trimmed_mean(op[0], op[1], op[4]),
    DEFENSE_CODES["krum"]:
        lambda op, it: flat_masked_krum(op[0], op[2], op[3], op[4]),
    DEFENSE_CODES["multi_krum"]:
        lambda op, it: flat_masked_krum(op[0], op[2], op[3], op[4]),
    DEFENSE_CODES["geometric_median"]:
        lambda op, it: flat_masked_geometric_median(op[0], op[4], iters=it),
}


def make_flat_defense_selector(codes: Optional[Sequence[int]] = None,
                               gm_iters: int = 8,
                               masked: bool = False) -> Callable:
    """Branchless defense dispatch for one lane: a `lax.switch` over the
    defense codes present in a sweep.

    Returns fn(code, flat, trim, num_byzantine, multi) -> [D], taking a
    trailing [U] bool participation-mask operand when masked=True.  Under
    `vmap` (code varying across lanes) the switch lowers to computing every
    listed branch and selecting per lane — which is why `codes` should be
    the codes a sweep actually contains (the default is all of
    DEFENSE_CODES): absent defenses then cost nothing.  Codes outside the
    list (e.g. analog lanes' 0 in a digital-only list) are remapped to the
    first branch; the caller overrides those lanes' output anyway.
    """
    if codes is None:
        codes = sorted(DEFENSE_CODES.values())
    codes = sorted({int(c) for c in codes})
    assert codes, "empty defense-code set"
    lookup = np.zeros(max(DEFENSE_CODES.values()) + 1, np.int32)
    for i, c in enumerate(codes):
        lookup[c] = i
    lookup_j = jnp.asarray(lookup)
    table = _MASKED_FLAT_KERNELS_BY_CODE if masked else _FLAT_KERNELS_BY_CODE
    branches = [functools.partial(table[c], it=gm_iters) for c in codes]

    if masked:
        def select(code, flat, trim, num_byzantine, multi, mask):
            return jax.lax.switch(lookup_j[code], branches,
                                  (flat, trim, num_byzantine, multi, mask))
    else:
        def select(code, flat, trim, num_byzantine, multi):
            return jax.lax.switch(lookup_j[code], branches,
                                  (flat, trim, num_byzantine, multi))

    return select


def make_group_defense_kernel(code: int, gm_iters: int = 8,
                              masked: bool = False) -> Callable:
    """Static single-family dispatch for a grouped lane partition
    (`scenario.build_lane_groups`): `code` is a concrete Python int, so the
    returned fn(flat [S_g, U, D], trim, f, multi each [S_g]) -> [S_g, D] is
    ONE family's kernel vmapped over its contiguous group — no `lax.switch`,
    no other family traced.  Per-lane math is identical to the switch
    selector's branch for `code` (same kernel-table entry), which is what
    makes grouped == switch dispatch exact.  masked=True appends a [S_g, U]
    bool participation-mask argument (same table as the masked selector)."""
    if masked:
        mfn = functools.partial(_MASKED_FLAT_KERNELS_BY_CODE[int(code)],
                                it=gm_iters)

        def apply_masked(flat, trim, num_byzantine, multi, mask):
            return jax.vmap(lambda f, t, nb, m, pk: mfn((f, t, nb, m, pk)))(
                flat, trim, num_byzantine, multi, mask)

        return apply_masked

    fn = functools.partial(_FLAT_KERNELS_BY_CODE[int(code)], it=gm_iters)

    def apply(flat, trim, num_byzantine, multi):
        return jax.vmap(lambda f, t, nb, m: fn((f, t, nb, m)))(
            flat, trim, num_byzantine, multi)

    return apply


# ----------------------------------------------------------- pytree wrappers


def coordinate_median(grads_u):
    flat, unravel = _flatten_u(grads_u)
    return unravel(flat_median(flat))


def trimmed_mean(grads_u, trim: int = 1):
    """Remove the `trim` largest and smallest per coordinate, then mean."""
    flat, unravel = _flatten_u(grads_u)
    return unravel(flat_trimmed_mean(flat, trim))


def krum(grads_u, num_byzantine: int, multi: int = 1):
    """(Multi-)Krum: score_i = sum of the U-f-2 smallest sq-distances to others;
    average the `multi` lowest-scoring workers' gradients."""
    flat, unravel = _flatten_u(grads_u)
    return unravel(flat_krum(flat, num_byzantine, multi))


def geometric_median(grads_u, iters: int = 8, eps: float = 1e-8):
    """Weiszfeld iterations for the geometric median."""
    flat, unravel = _flatten_u(grads_u)
    return unravel(flat_geometric_median(flat, iters=iters, eps=eps))


DEFENSES: Dict[str, Callable] = {
    "median": coordinate_median,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "geometric_median": geometric_median,
    "mean": lambda g: jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), g),
}


def digital_aggregate(grads_u, defense: str = "mean", **kw):
    """Gather-based digital aggregation with a named defense."""
    fn = DEFENSES[defense]
    return fn(grads_u, **kw) if kw else fn(grads_u)
