"""Digital-FL Byzantine-robust aggregation baselines (paper §I related work).

The paper's motivation: screening defenses (median/Krum/...) need *individual*
local gradients, which analog aggregation hides — so they cannot be applied to
FLOA.  We implement them anyway for the *digital* comparison mode (per-worker
gradients explicitly gathered), so experiments can quantify the robustness /
communication-cost trade-off the paper argues about:

  coordinate-wise median           [Yin et al. 2018]
  coordinate-wise trimmed mean     [Yin et al. 2018]
  Krum / Multi-Krum                [Blanchard et al. 2017]
  geometric median (Weiszfeld)     [Minsker 2015 / RFA]

All operate on stacked per-worker gradient pytrees [U, ...] and are jit-safe.
NOTE: in digital mode the [U, ...] stack must be gathered (an all-gather over
"data" instead of FLOA's all-reduce) — exactly the communication overhead the
paper's analog scheme avoids; the roofline benchmarks expose the difference.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


def _flatten_u(grads_u):
    """[U, ...] pytree -> ([U, D] matrix, unravel fn)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_u)
    u = leaves[0].shape[0]
    flat = jnp.concatenate([x.reshape(u, -1).astype(jnp.float32) for x in leaves], axis=1)

    def unravel(vec):
        out, off = [], 0
        for x in leaves:
            n = int(x.size) // u
            out.append(vec[off : off + n].reshape(x.shape[1:]).astype(x.dtype))
            off += n
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unravel


def coordinate_median(grads_u):
    flat, unravel = _flatten_u(grads_u)
    return unravel(jnp.median(flat, axis=0))


def trimmed_mean(grads_u, trim: int = 1):
    """Remove the `trim` largest and smallest per coordinate, then mean."""
    flat, unravel = _flatten_u(grads_u)
    u = flat.shape[0]
    assert 2 * trim < u, "trim too large"
    srt = jnp.sort(flat, axis=0)
    return unravel(jnp.mean(srt[trim : u - trim], axis=0))


def krum(grads_u, num_byzantine: int, multi: int = 1):
    """(Multi-)Krum: score_i = sum of the U-f-2 smallest sq-distances to others;
    average the `multi` lowest-scoring workers' gradients."""
    flat, unravel = _flatten_u(grads_u)
    u = flat.shape[0]
    closest = max(u - num_byzantine - 2, 1)
    d2 = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)  # [U,U]
    d2 = d2 + jnp.eye(u) * jnp.inf  # exclude self
    nearest = jnp.sort(d2, axis=1)[:, :closest]
    scores = jnp.sum(nearest, axis=1)
    sel = jnp.argsort(scores)[:multi]
    return unravel(jnp.mean(flat[sel], axis=0))


def geometric_median(grads_u, iters: int = 8, eps: float = 1e-8):
    """Weiszfeld iterations for the geometric median."""
    flat, unravel = _flatten_u(grads_u)

    def body(z, _):
        w = 1.0 / jnp.maximum(jnp.linalg.norm(flat - z, axis=1), eps)  # [U]
        z = jnp.sum(w[:, None] * flat, axis=0) / jnp.sum(w)
        return z, None

    z0 = jnp.mean(flat, axis=0)
    z, _ = jax.lax.scan(body, z0, None, length=iters)
    return unravel(z)


DEFENSES: Dict[str, Callable] = {
    "median": coordinate_median,
    "trimmed_mean": trimmed_mean,
    "krum": krum,
    "geometric_median": geometric_median,
    "mean": lambda g: jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), g),
}


def digital_aggregate(grads_u, defense: str = "mean", **kw):
    """Gather-based digital aggregation with a named defense."""
    fn = DEFENSES[defense]
    return fn(grads_u, **kw) if kw else fn(grads_u)
