"""Public API of the BEV-SGD reproduction.

Everything in `__all__` is the supported surface: the compiled sweep engine
and its `ExecutionPlan` strategy object, the scenario/spec builders, the
frozen config dataclasses they consume, and the sweep-mesh constructor.
Deeper modules (`repro.core.*`, `repro.kernels.*`, `repro.launch.*`) are
implementation detail — importable, but their layout may shift between PRs;
examples, benchmarks, and docs snippets import from here (or the `repro.fl` /
`repro.configs` / `repro.models` package roots) only.
"""
from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    DefenseSpec,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
    noise_std_for_snr,
)
from repro.fl import (
    ExecutionPlan,
    FLTrainer,
    RoundLog,
    ScenarioCase,
    SweepEngine,
    SweepResult,
    SweepSpec,
    run_sweep,
)
from repro.launch.mesh import make_sweep_mesh

__all__ = [
    "AttackConfig",
    "AttackType",
    "ChannelConfig",
    "DefenseSpec",
    "ExecutionPlan",
    "FLOAConfig",
    "FLTrainer",
    "Policy",
    "PowerConfig",
    "RoundLog",
    "ScenarioCase",
    "SweepEngine",
    "SweepResult",
    "SweepSpec",
    "first_n_mask",
    "make_sweep_mesh",
    "noise_std_for_snr",
    "run_sweep",
]
