"""Public API of the BEV-SGD reproduction.

Everything in `__all__` is the supported surface: the compiled sweep engine
and its `ExecutionPlan` strategy object, the scenario/spec builders, the
frozen config dataclasses they consume, the sweep-mesh constructor, the
generic pytree checkpoint API (`save_pytree` / `restore_pytree` /
`latest_step` — what preemption-safe resume persists with), and the
multi-host bootstrap (`initialize_distributed` / `setup_compilation_cache`).
Deeper modules (`repro.core.*`, `repro.kernels.*`, `repro.launch.*`) are
implementation detail — importable, but their layout may shift between PRs;
examples, benchmarks, and docs snippets import from here (or the `repro.fl` /
`repro.configs` / `repro.models` package roots) only.
"""
from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    DefenseSpec,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
    noise_std_for_snr,
)
from repro.fl import (
    ExecutionPlan,
    FLTrainer,
    RoundLog,
    ScenarioCase,
    SweepEngine,
    SweepResult,
    SweepSpec,
    run_sweep,
)
from repro.launch.distributed import (
    fetch,
    initialize_distributed,
    setup_compilation_cache,
)
from repro.launch.mesh import make_sweep_mesh

__all__ = [
    "AttackConfig",
    "AttackType",
    "ChannelConfig",
    "DefenseSpec",
    "ExecutionPlan",
    "FLOAConfig",
    "FLTrainer",
    "Policy",
    "PowerConfig",
    "RoundLog",
    "ScenarioCase",
    "SweepEngine",
    "SweepResult",
    "SweepSpec",
    "fetch",
    "first_n_mask",
    "initialize_distributed",
    "latest_step",
    "make_sweep_mesh",
    "noise_std_for_snr",
    "restore_pytree",
    "run_sweep",
    "save_pytree",
    "setup_compilation_cache",
]
