"""Shared model substrate: configs, parameter factory, norms, RoPE, embeddings.

Functional style (no flax): `init_*` builds nested param dicts through a
ParamFactory which records a parallel PartitionSpec tree, so `jax.jit`
in_shardings can be derived mechanically for any mesh.  Sharding specs are
*legal by construction*: a dim is annotated with a mesh axis only if its size
divides the axis size declared in `cfg.model_parallel` (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array

# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                     # per-expert FFN hidden size
    num_shared: int = 0               # shared (always-on) experts
    interleave: int = 1               # every `interleave`-th block is MoE (1 = all)
    capacity_factor: float = 1.25
    impl: str = "capacity_gather"     # or "scan_dense" (masked full compute)
    router_aux_coef: float = 0.01     # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    chunk: int = 256
    d_conv: int = 4
    ngroups: int = 1


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 12
    n_dec_layers: int = 12
    enc_seq_cap: int = 4096           # encoder (stub-frontend) sequence length cap


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    kind: str                         # "vision" | "audio" (stubbed per spec)
    feature_dim: int = 1024
    n_prefix: int = 2880              # vision: anyres patch count; audio: n/a


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None      # native sliding window (None = full attn)
    long_context_window: Optional[int] = None  # SWA used only for long_500k
    block_pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendConfig] = None
    rglru_width: Optional[int] = None  # RG-LRU recurrent width (default d_model)
    local_window: int = 2048           # window of "local_attn" blocks (hybrid)
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    model_parallel: int = 1            # size of the "model" mesh axis for specs
    remat: bool = True
    scan_layers: bool = True
    norm_eps: float = 1e-6
    citation: str = ""
    # decode-shape applicability (set by configs; dryrun consults this)
    skip_shapes: Tuple[str, ...] = ()
    # analysis mode: replace scans/maps with Python loops so XLA cost
    # analysis (which counts while bodies ONCE) sees every layer/chunk/expert.
    # Used by the dry-run cost probes only — never for real execution.
    unroll_for_analysis: bool = False
    # CE/logits are computed in sequence chunks of this many positions so the
    # [B, S, vocab] tensor never materializes (163k-vocab configs would need
    # >100 GB/device otherwise).
    lm_head_chunk: int = 1024
    # decode KV cache storage: "native" (= cfg.dtype) or "int8" (per-position,
    # per-head absmax quantization — §Perf memory-term optimization; decode is
    # cache-bandwidth-bound so int8 halves the dominant roofline term).
    kv_cache_dtype: str = "native"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        mult = 256
        return ((self.vocab_size + mult - 1) // mult) * mult

    def shard(self, size: int, axis: str = "model"):
        """Return `axis` if `size` divides the model-parallel degree, else None."""
        return axis if size % max(self.model_parallel, 1) == 0 else None


# ---------------------------------------------------------------------------
# parameter factory
# ---------------------------------------------------------------------------


class ParamFactory:
    """Builds a nested params dict + a parallel PartitionSpec dict.

    Usage:
        fac = ParamFactory(key, dtype=jnp.bfloat16)
        w = fac.param("attn.wq", (d, h, hd), P(None, "model", None), fan_in=d)
        params, specs = fac.collect()
    Dots in names create nesting.  `fan_in` selects truncated-normal scale
    1/sqrt(fan_in); `init="zeros"|"ones"` for norm scales / biases.
    """

    def __init__(self, key: Array, dtype=jnp.bfloat16, shape_only: bool = False):
        self._key = key
        self._count = 0
        self.dtype = dtype
        self.shape_only = shape_only  # record specs/shapes without allocating
        self._params: Dict[str, Array] = {}
        self._specs: Dict[str, P] = {}

    def _next_key(self) -> Array:
        self._count += 1
        return jax.random.fold_in(self._key, self._count)

    def param(self, name, shape, spec=None, fan_in=None, init="normal", dtype=None):
        dtype = dtype or self.dtype
        if self.shape_only:
            val = jax.ShapeDtypeStruct(tuple(shape), dtype)
        elif init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype)
        else:
            scale = 1.0 / math.sqrt(fan_in if fan_in else shape[0])
            val = (
                jax.random.truncated_normal(self._next_key(), -2.0, 2.0, shape, jnp.float32)
                * scale
            ).astype(dtype)
        assert name not in self._params, f"duplicate param {name}"
        self._params[name] = val
        self._specs[name] = spec if spec is not None else P()
        return val

    def collect(self):
        return _nest(self._params), _nest(self._specs)


def _nest(flat: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split(".")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out


def stack_layer_params(init_one, key: Array, n: int):
    """Init `n` copies of a layer and stack leaves along a new leading axis.

    init_one(key) -> (params, specs).  Returns (stacked_params, specs_with_
    leading_None).  Used for scan-over-layers.
    """
    keys = jax.random.split(key, n)
    p0, s0 = init_one(keys[0])
    leaves0 = jax.tree_util.tree_leaves(p0)
    if leaves0 and isinstance(leaves0[0], jax.ShapeDtypeStruct):  # shape-only
        stacked = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct((n,) + tuple(x.shape), x.dtype), p0
        )
        specs = jax.tree_util.tree_map(
            lambda s: P(*((None,) + tuple(s))), s0,
            is_leaf=lambda x: isinstance(x, P),
        )
        return stacked, specs
    rest = [init_one(k)[0] for k in keys[1:]]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), p0, *rest)
    specs = jax.tree_util.tree_map(
        lambda s: P(*((None,) + tuple(s))), s0,
        is_leaf=lambda x: isinstance(x, P),
    )
    return stacked, specs


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H?, Dh] rotated pairwise; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    # broadcast over any head axes between S and Dh
    while ang.ndim < x.ndim:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return xr.reshape(x.shape).astype(x.dtype)


def make_causal_mask(sq: int, sk: int, q_offset, window: Optional[int]) -> Array:
    """Boolean [Sq, Sk] mask (True = attend).  q position = q_offset + i."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def softmax_xent(logits: Array, labels: Array, vocab: int) -> Array:
    """Stable CE over possibly vocab-padded logits.  logits [..., Vp], labels [...]."""
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp > vocab:  # mask padding ids out of the partition function
        pad_mask = jnp.arange(vp) >= vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - ll


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# activation sharding hints
# ---------------------------------------------------------------------------
# XLA's sharding propagation resolves conflicts (sequence-sharded residuals x
# head-sharded weights) unpredictably; unhinted attention internals can end up
# replicated (a single unhinted 128-head MLA layer peaks at 41 GB/device).
# Step builders install a context; model code marks tensors with a compact
# dim-code string: 'b' = batch axes, 'm' = "model" (if the dim divides the
# mesh), '.' = unconstrained.  Without a context the hints are no-ops, so
# single-host code paths are untouched.

import contextvars as _contextvars

_SHARD_CTX = _contextvars.ContextVar("repro_shard_ctx", default=None)


def set_sharding_context(mesh, batch_axes: tuple, model_size: int):
    """Install hints for the current trace; returns a token for reset()."""
    return _SHARD_CTX.set((mesh, tuple(batch_axes), model_size))


def reset_sharding_context(token) -> None:
    _SHARD_CTX.reset(token)


def shard_hint(x: Array, dims: str) -> Array:
    ctx = _SHARD_CTX.get()
    if ctx is None:
        return x
    mesh, baxes, mp = ctx
    from jax.sharding import NamedSharding

    assert len(dims) == x.ndim, (dims, x.shape)
    spec = []
    for ch, size in zip(dims, x.shape):
        if ch == "b":
            spec.append(baxes if len(baxes) > 1 else baxes[0])
        elif ch == "m" and mp > 1 and size % mp == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def maybe_scan(body, init, xs, unroll: bool):
    """lax.scan, or an unrolled Python loop in analysis mode (see
    ModelConfig.unroll_for_analysis).  body(carry, x) -> (carry, y)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0] if xs is not None else 0
    carry, ys = init, []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def maybe_map(fn, xs, unroll: bool):
    """lax.map, or an unrolled Python loop in analysis mode."""
    if not unroll:
        return jax.lax.map(fn, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = [fn(jax.tree_util.tree_map(lambda a: a[i], xs)) for i in range(n)]
    return jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
