"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked dual form for train/prefill: within a chunk the quadratic
(attention-like) form, across chunks a linear recurrence over the
[H, P, N] states (lax.scan over n_chunks — 16 chunks at 4k).
Decode: the classic recurrent update, O(1) state
  state <- state * exp(dt*A) + dt * B (outer) x;  y = C . state
so long_500k decode carries a constant [B, H, P, N] state (no KV cache).

Layout: d_inner = expand*d_model, H = d_inner/headdim heads sharded on
"model"; B/C are grouped (ngroups, broadcast over heads).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ParamFactory, rms_norm, shard_hint

Array = jax.Array


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.headdim
    return s, d_in, nheads


def init_ssm(fac: ParamFactory, pre: str, cfg: ModelConfig) -> None:
    s, d_in, nheads = _dims(cfg)
    d = cfg.d_model
    conv_ch = d_in + 2 * s.ngroups * s.d_state
    hs = cfg.shard(nheads)
    fac.param(f"{pre}.in_proj",
              (d, d_in + conv_ch + nheads),
              P(None, cfg.shard(d_in + conv_ch + nheads)), fan_in=d)
    fac.param(f"{pre}.conv_w", (s.d_conv, conv_ch), P(None, None), fan_in=s.d_conv)
    fac.param(f"{pre}.conv_b", (conv_ch,), P(None), init="zeros")
    fac.param(f"{pre}.A_log", (nheads,), P(hs), init="zeros")
    fac.param(f"{pre}.D", (nheads,), P(hs), init="zeros")
    fac.param(f"{pre}.dt_bias", (nheads,), P(hs), init="zeros")
    fac.param(f"{pre}.norm", (d_in,), P(cfg.shard(d_in)), init="zeros")
    fac.param(f"{pre}.out_proj", (d_in, d), P(cfg.shard(d_in), None), fan_in=d_in)


def _split_proj(proj: Array, cfg: ModelConfig):
    s, d_in, nheads = _dims(cfg)
    gn = s.ngroups * s.d_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + d_in + 2 * gn]
    dt = proj[..., d_in + d_in + 2 * gn :]
    return z, xbc, dt


def _split_xbc(xbc: Array, cfg: ModelConfig):
    s, d_in, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    x = xbc[..., :d_in]
    b = xbc[..., d_in : d_in + gn]
    c = xbc[..., d_in + gn :]
    return x, b, c


def _causal_conv(xbc: Array, w: Array, bias: Array) -> Array:
    """Depthwise causal conv1d over [B,S,C] with kernel [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + bias)


def ssd_full(p: Dict, u: Array, cfg: ModelConfig) -> Array:
    """Mamba-2 block over a full sequence.  u [B,S,d] -> [B,S,d]."""
    s, d_in, nheads = _dims(cfg)
    bsz, slen, _ = u.shape
    q = s.chunk
    if slen % q:  # right-pad to a chunk multiple (padding can't leak: causal)
        pad = q - slen % q
        out = ssd_full(p, jnp.pad(u, ((0, 0), (0, pad), (0, 0))), cfg)
        return out[:, :slen]
    nck = slen // q

    proj = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x, bmat, cmat = _split_xbc(xbc, cfg)

    xh = shard_hint(x.reshape(bsz, slen, nheads, s.headdim), "b.m.")
    bmat = bmat.reshape(bsz, slen, s.ngroups, s.d_state)
    cmat = cmat.reshape(bsz, slen, s.ngroups, s.d_state)
    # broadcast groups over heads
    rep = nheads // s.ngroups
    bh = jnp.repeat(bmat, rep, axis=2)  # [B,S,H,N]
    ch = jnp.repeat(cmat, rep, axis=2)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))             # [H], negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    da = dt * a                                              # [B,S,H]

    # chunk views
    def ck(t):
        return t.reshape(bsz, nck, q, *t.shape[2:])

    xc, bc, cc, dac, dtc = map(ck, (xh, bh, ch, da, dt))

    # intra-chunk (quadratic) term
    cs = jnp.cumsum(dac, axis=2)                             # [B,C,Q,H]
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]        # [B,C,Q,Q,H] (i,j)
    causal = jnp.tril(jnp.ones((q, q), bool))
    l = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", cc, bc).astype(jnp.float32)
    att = scores * l * dtc[:, :, None, :, :]                 # weight dt at source
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", att.astype(xc.dtype), xc)

    # chunk states: S_c = sum_j exp(cs_last - cs_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)            # [B,C,Q,H]
    wts = (decay_to_end * dtc).astype(xc.dtype)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", wts, bc, xc)

    # inter-chunk recurrence over C (sequential scan, nck small; f32 state)
    chunk_decay = jnp.exp(jnp.sum(dac, axis=2))              # [B,C,H]

    def body(carry, inp):
        st, dec = inp                                        # [B,H,N,P],[B,H]
        new = carry * dec[..., None, None] + st.astype(jnp.float32)
        return new, carry                                    # emit state BEFORE chunk

    from repro.models.common import maybe_scan

    init = jnp.zeros(states[:, 0].shape, jnp.float32)
    _, prev_states = maybe_scan(
        body, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
        cfg.unroll_for_analysis,
    )
    prev_states = prev_states.swapaxes(0, 1)                 # [B,C,H,N,P]

    # contribution of the entering state to each position
    decay_from_start = jnp.exp(cs)                           # [B,C,Q,H]
    y_off = jnp.einsum(
        "bcqhn,bchnp,bcqh->bcqhp", cc, prev_states.astype(cc.dtype),
        decay_from_start.astype(cc.dtype),
    )

    y = (y_diag + y_off).reshape(bsz, slen, nheads, s.headdim)
    y = y + xh * p["D"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(bsz, slen, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    s, d_in, nheads = _dims(cfg)
    conv_ch = d_in + 2 * s.ngroups * s.d_state
    return dict(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, nheads, s.d_state, s.headdim), jnp.float32),
    )


def ssd_decode_step(p: Dict, u1: Array, state: Dict, cfg: ModelConfig
                    ) -> Tuple[Array, Dict]:
    """One-token recurrent update.  u1 [B,1,d]."""
    s, d_in, nheads = _dims(cfg)
    bsz = u1.shape[0]
    proj = jnp.einsum("bsd,de->bse", u1, p["in_proj"])[:, 0]
    z, xbc, dt = _split_proj(proj, cfg)
    # conv over (state, new)
    window = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # [B,K,C]
    conv = jnp.sum(window * p["conv_w"], axis=1) + p["conv_b"]
    xbc = jax.nn.silu(conv)
    x, bvec, cvec = _split_xbc(xbc, cfg)
    xh = x.reshape(bsz, nheads, s.headdim)
    rep = nheads // s.ngroups
    bh = jnp.repeat(bvec.reshape(bsz, s.ngroups, s.d_state), rep, axis=1)
    chd = jnp.repeat(cvec.reshape(bsz, s.ngroups, s.d_state), rep, axis=1)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                  # [B,H]
    upd = (dt[..., None, None] * bh[..., :, None].astype(jnp.float32)
           * xh[..., None, :].astype(jnp.float32))           # [B,H,N,P]
    new_ssm = state["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", chd.astype(jnp.float32), new_ssm)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_in).astype(u1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None]
    new_state = dict(conv=window[:, 1:], ssm=new_ssm)
    return out, new_state
