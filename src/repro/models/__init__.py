"""Model zoo: assigned architectures + the paper's MLP."""
from repro.models.mlp import (  # noqa: F401
    init_mlp,
    mlp_accuracy,
    mlp_logits,
    mlp_loss,
)

__all__ = ["init_mlp", "mlp_accuracy", "mlp_logits", "mlp_loss"]
