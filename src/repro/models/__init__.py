"""Model zoo: assigned architectures + the paper's MLP."""
