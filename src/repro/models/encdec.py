"""Encoder-decoder backbone (SeamlessM4T-v2 large's text/speech transformer).

The modality frontend (mel + conv codec) is stubbed per spec: the encoder
consumes precomputed frame embeddings [B, T_frames, feature_dim].  Everything
else — bidirectional encoder stack, causal decoder with cross-attention,
decode-time KV caching — is real.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as ATT
from repro.models import ffn as FFN
from repro.models.common import (
    ModelConfig,
    ParamFactory,
    maybe_map,
    maybe_scan,
    rms_norm,
    softmax_xent,
    stack_layer_params,
)

Array = jax.Array
Identity = lambda x: x  # noqa: E731


def _init_enc_block(key, cfg: ModelConfig, shape_only: bool = False):
    fac = ParamFactory(key, dtype=cfg.dtype, shape_only=shape_only)
    d = cfg.d_model
    fac.param("ln1", (d,), P(None), init="zeros")
    ATT.init_gqa(fac, "attn", cfg)
    fac.param("ln2", (d,), P(None), init="zeros")
    FFN.init_swiglu(fac, "ffn", cfg)
    return fac.collect()


def _init_dec_block(key, cfg: ModelConfig, shape_only: bool = False):
    fac = ParamFactory(key, dtype=cfg.dtype, shape_only=shape_only)
    d = cfg.d_model
    fac.param("ln1", (d,), P(None), init="zeros")
    ATT.init_gqa(fac, "self_attn", cfg)
    fac.param("ln_x", (d,), P(None), init="zeros")
    ATT.init_gqa(fac, "cross_attn", cfg)
    fac.param("ln2", (d,), P(None), init="zeros")
    FFN.init_swiglu(fac, "ffn", cfg)
    return fac.collect()


def init_encdec(key: Array, cfg: ModelConfig, shape_only: bool = False):
    ed = cfg.encdec
    k1, k2, k3 = jax.random.split(key, 3)
    fac = ParamFactory(k1, dtype=cfg.dtype, shape_only=shape_only)
    vp, d = cfg.padded_vocab, cfg.d_model
    fd = cfg.frontend.feature_dim if cfg.frontend else d
    fac.param("enc_in", (fd, d), P(None, cfg.shard(d)), fan_in=fd)
    fac.param("enc_norm", (d,), P(None), init="zeros")
    fac.param("embed", (vp, d), P(cfg.shard(vp), None), fan_in=d)
    fac.param("dec_norm", (d,), P(None), init="zeros")
    fac.param("lm_head", (d, vp), P(None, cfg.shard(vp)), fan_in=d)
    params, specs = fac.collect()
    params["enc_blocks"], specs["enc_blocks"] = stack_layer_params(
        lambda k: _init_enc_block(k, cfg, shape_only), k2, ed.n_enc_layers
    )
    params["dec_blocks"], specs["dec_blocks"] = stack_layer_params(
        lambda k: _init_dec_block(k, cfg, shape_only), k3, ed.n_dec_layers
    )
    return params, specs


def encode(params: Dict, frames: Array, cfg: ModelConfig,
           constrain: Callable = Identity) -> Array:
    """frames [B,T,feat] -> encoder output [B,T,d] (bidirectional)."""
    x = jnp.einsum("btf,fd->btd", frames.astype(cfg.dtype), params["enc_in"])
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def block(p, xx):
        h = rms_norm(xx, p["ln1"], cfg.norm_eps)
        xx = constrain(xx + ATT.gqa_full(p["attn"], h, cfg, positions, causal=False))
        h2 = rms_norm(xx, p["ln2"], cfg.norm_eps)
        return constrain(xx + FFN.swiglu(p["ffn"], h2))

    if cfg.remat:
        block = jax.checkpoint(block)
    x, _ = maybe_scan(lambda xx, p: (block(p, xx), None), x,
                      params["enc_blocks"], cfg.unroll_for_analysis)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_hidden(params: Dict, tokens: Array, enc_out: Array, cfg: ModelConfig,
                  constrain: Callable = Identity) -> Array:
    """Teacher-forced decoder pass -> final hidden [B,S,d]."""
    x = params["embed"][tokens]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def block(p, xx):
        h = rms_norm(xx, p["ln1"], cfg.norm_eps)
        xx = constrain(xx + ATT.gqa_full(p["self_attn"], h, cfg, positions))
        hx = rms_norm(xx, p["ln_x"], cfg.norm_eps)
        kv = ATT.encode_kv(p["cross_attn"], enc_out, cfg)
        xx = constrain(xx + ATT.cross_attention(p["cross_attn"], hx, kv, cfg))
        h2 = rms_norm(xx, p["ln2"], cfg.norm_eps)
        return constrain(xx + FFN.swiglu(p["ffn"], h2))

    if cfg.remat:
        block = jax.checkpoint(block)
    x, _ = maybe_scan(lambda xx, p: (block(p, xx), None), x,
                      params["dec_blocks"], cfg.unroll_for_analysis)
    return rms_norm(x, params["dec_norm"], cfg.norm_eps)


def decode_full(params: Dict, tokens: Array, enc_out: Array, cfg: ModelConfig,
                constrain: Callable = Identity,
                constrain_logits: Callable = Identity) -> Array:
    """Teacher-forced decoder pass -> logits [B,S,Vp]."""
    h = decode_hidden(params, tokens, enc_out, cfg, constrain)
    return constrain_logits(jnp.einsum("bsd,dv->bsv", h, params["lm_head"]))


def encdec_per_example_loss(params: Dict, batch: Dict, cfg: ModelConfig,
                            constrain: Callable = Identity,
                            constrain_logits: Callable = Identity) -> Array:
    """Per-sequence mean CE [B] (see lm_per_example_loss).  The lm_head is
    applied in sequence chunks (256k vocab would not fit otherwise)."""
    from repro.models.transformer import chunked_ce

    enc_out = encode(params, batch["frames"], cfg, constrain)
    h = decode_hidden(params, batch["tokens"][:, :-1], enc_out, cfg, constrain)
    ce = chunked_ce(params, h, batch["tokens"][:, 1:], cfg, constrain_logits)
    return jnp.mean(ce, axis=-1)


def encdec_loss(params: Dict, batch: Dict, cfg: ModelConfig,
                constrain: Callable = Identity,
                constrain_logits: Callable = Identity) -> Array:
    return jnp.mean(encdec_per_example_loss(
        params, batch, cfg, constrain, constrain_logits))


# --- decode ------------------------------------------------------------------


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    ed = cfg.encdec
    one = ATT.init_cache(cfg, batch, max_len, None, cfg.dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (ed.n_dec_layers,) + x.shape), one
    )


def precompute_cross_kv(params: Dict, enc_out: Array, cfg: ModelConfig):
    """Per-decoder-layer cross K/V, stacked [L, B, Se, KV, hd] x2."""
    def one(p):
        return ATT.encode_kv(p["cross_attn"], enc_out, cfg)

    return jax.vmap(one)(params["dec_blocks"])


def decode_step(params: Dict, caches: Dict, cross_kv, tokens1: Array,
                pos: Array, cfg: ModelConfig,
                constrain_logits: Callable = Identity):
    """One decoder token.  cross_kv from precompute_cross_kv."""
    x = params["embed"][tokens1]

    def body(x1, inp):
        p, c, ckv = inp
        h = rms_norm(x1, p["ln1"], cfg.norm_eps)
        h, c = ATT.decode_step(p["self_attn"], h, c, pos, cfg)
        x1 = x1 + h
        hx = rms_norm(x1, p["ln_x"], cfg.norm_eps)
        x1 = x1 + ATT.cross_attention(p["cross_attn"], hx, ckv, cfg)
        h2 = rms_norm(x1, p["ln2"], cfg.norm_eps)
        x1 = x1 + FFN.swiglu(p["ffn"], h2)
        return x1, c

    x, new_caches = maybe_scan(body, x, (params["dec_blocks"], caches, cross_kv),
                               cfg.unroll_for_analysis)
    h = rms_norm(x, params["dec_norm"], cfg.norm_eps)
    logits = constrain_logits(jnp.einsum("bsd,dv->bsv", h, params["lm_head"]))
    return logits, new_caches
