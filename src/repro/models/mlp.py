"""The paper's experiment model (§IV): MLP 784-64-10, ReLU, cross-entropy.

D = 784*64 + 64 + 64*10 + 10 = 50890 parameters, matching the paper exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def init_mlp(key: Array, d_in: int = 784, d_hidden: int = 64, n_classes: int = 10):
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (d_in, d_hidden), jnp.float32)
        * (2.0 / d_in) ** 0.5,
        "b1": jnp.zeros((d_hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (d_hidden, n_classes), jnp.float32)
        * (2.0 / d_hidden) ** 0.5,
        "b2": jnp.zeros((n_classes,), jnp.float32),
    }
    return params


def mlp_logits(params: Dict, x: Array) -> Array:
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params: Dict, batch: Dict) -> Array:
    """Cross-entropy; batch = {"x": [B,784], "y": [B] int}."""
    logits = mlp_logits(params, batch["x"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    return -jnp.mean(ll)


def mlp_accuracy(params: Dict, x: Array, y: Array) -> Array:
    return jnp.mean(jnp.argmax(mlp_logits(params, x), axis=-1) == y)


def num_params(params: Dict) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
