"""Mixture-of-Experts: router, two dispatch implementations, shared experts.

Dispatch impls (cfg.moe.impl):
  * "scan_dense": lax.scan over experts, every expert computes every token,
    masked by the router's combine weights.  Memory-light, compact HLO,
    compiles for any sharding — but overcomputes by num_experts/top_k (the
    roofline's MODEL_FLOPS/HLO_FLOPs ratio exposes this; it is the §Perf
    hillclimb baseline).
  * "capacity_gather": sort-based token->expert buckets with capacity
    C = ceil(top_k*T/E * capacity_factor); experts compute only their bucket
    ([E, C, d] batch, E sharded on "model").  ~E/top_k less compute; tokens
    overflowing capacity are dropped (standard GShard semantics).

Expert weights are stacked [E, ...] with E sharded on "model" (expert
parallelism — 160/16, 128/16, 64/16 all divide the production mesh).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ParamFactory
from repro.models.ffn import init_swiglu, swiglu

Array = jax.Array


def init_moe(fac: ParamFactory, pre: str, cfg: ModelConfig) -> None:
    d, m = cfg.d_model, cfg.moe
    e, f = m.num_experts, m.d_expert
    fac.param(f"{pre}.router", (d, e), P(None, None), fan_in=d, dtype=jnp.float32)
    if m.impl == "scan_dense":
        # scan iterates experts one at a time: shard the expert FFN width on
        # "model" (tensor parallel within each expert step)
        fs = cfg.shard(f)
        fac.param(f"{pre}.w1", (e, d, f), P(None, None, fs), fan_in=d)
        fac.param(f"{pre}.wg", (e, d, f), P(None, None, fs), fan_in=d)
        fac.param(f"{pre}.w2", (e, f, d), P(None, fs, None), fan_in=f)
    else:
        # bucketed dispatch computes all experts at once: expert parallelism
        es = cfg.shard(e)
        fac.param(f"{pre}.w1", (e, d, f), P(es, None, None), fan_in=d)
        fac.param(f"{pre}.wg", (e, d, f), P(es, None, None), fan_in=d)
        fac.param(f"{pre}.w2", (e, f, d), P(es, None, None), fan_in=f)
    if m.num_shared:
        init_swiglu(fac, f"{pre}.shared", cfg, d_ff=m.num_shared * f)


def router_probs(p: Dict, x: Array, cfg: ModelConfig) -> Array:
    """[T, E] softmax router probabilities in f32."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs: Array, idx: Array, num_experts: int) -> Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, num_experts, dtype=jnp.float32), axis=1), axis=0
    )  # fraction routed to each expert (counting top-k slots)
    pbar = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * pbar)


def _expert_ffn(w1: Array, wg: Array, w2: Array, x: Array) -> Array:
    from repro.models.common import shard_hint

    h = jax.nn.silu(shard_hint(x @ w1, "bm")) * (x @ wg)
    return h @ w2


def moe_scan_dense(p: Dict, x2: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """x2 [T, d] -> ([T, d], aux_loss). Masked full compute, scan over experts."""
    m = cfg.moe
    probs = router_probs(p, x2, cfg)                          # [T,E]
    gates, idx = jax.lax.top_k(probs, m.top_k)                # [T,k]
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # combine weight per (token, expert): scatter the top-k gates
    comb = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], idx
    ].set(gates)                                              # [T,E]

    @jax.checkpoint
    def expert_contrib(w1, wg, w2, w_col, x):
        # w_col multiply INSIDE the checkpoint: otherwise scan-AD saves the
        # [T, d] expert output o for every expert (it is needed for dL/dw_col)
        # -> 2 GB/device/expert.  Rematerializing keeps residuals at O(inputs).
        o = _expert_ffn(w1, wg, w2, x)
        return w_col[:, None].astype(o.dtype) * o

    def body(y, packed):
        w1, wg, w2, w_col = packed
        return y + expert_contrib(w1, wg, w2, w_col, x2), None

    from repro.models.common import maybe_scan

    y0 = jnp.zeros_like(x2)
    y, _ = maybe_scan(body, y0, (p["w1"], p["wg"], p["w2"], comb.T),
                      cfg.unroll_for_analysis)
    aux = load_balance_loss(probs, idx, m.num_experts)
    return y, aux


def moe_capacity_gather(p: Dict, x2: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """x2 [T, d] -> ([T, d], aux). Sort-based bucketed dispatch with capacity."""
    m = cfg.moe
    t, d = x2.shape
    e, k = m.num_experts, m.top_k
    cap = int(-(-k * t // e) * m.capacity_factor)
    cap = max(cap, 1)

    probs = router_probs(p, x2, cfg)
    gates, idx = jax.lax.top_k(probs, k)                      # [T,k]
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    from repro.models.common import shard_hint

    flat_e = idx.reshape(-1)                                  # [T*k]
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sg, stok = flat_e[order], flat_g[order], flat_tok[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e))           # [E]
    rank = jnp.arange(t * k) - seg_start[se]
    ok = rank < cap
    slot = jnp.where(ok, se * cap + rank, e * cap)            # OOB -> dropped

    buf = jnp.zeros((e * cap, d), x2.dtype).at[slot].set(
        x2[stok], mode="drop")
    xe = shard_hint(buf.reshape(e, cap, d), "m..")            # expert-parallel
    he = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["wg"]
    )
    he = shard_hint(he, "m..")
    ye = shard_hint(jnp.einsum("ecf,efd->ecd", he, p["w2"]), "m..")
    ye = ye.reshape(e * cap, d)

    out_tok = jnp.where(ok[:, None], ye[jnp.minimum(slot, e * cap - 1)], 0.0)
    y = jnp.zeros_like(x2).at[stok].add(
        (sg * ok)[:, None].astype(x2.dtype) * out_tok
    )
    y = shard_hint(y, "bm")
    aux = load_balance_loss(probs, idx, e)
    return y, aux


def moe_ffn(p: Dict, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """[B,S,d] -> ([B,S,d], aux_loss); adds shared experts if configured."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    impl = moe_scan_dense if cfg.moe.impl == "scan_dense" else moe_capacity_gather
    y2, aux = impl(p, x2, cfg)
    y = y2.reshape(b, s, d)
    if cfg.moe.num_shared:
        y = y + swiglu(p["shared"], x)
    return y, aux
