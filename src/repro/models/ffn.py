"""Feed-forward layers: SwiGLU (dense) and plain GELU MLP."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ParamFactory, shard_hint

Array = jax.Array


def init_swiglu(fac: ParamFactory, pre: str, cfg: ModelConfig, d_ff: int = 0) -> None:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    fs = cfg.shard(f)
    fac.param(f"{pre}.wi", (d, f), P(None, fs), fan_in=d)       # gate
    fac.param(f"{pre}.wg", (d, f), P(None, fs), fan_in=d)       # up
    fac.param(f"{pre}.wo", (f, d), P(fs, None), fan_in=f)       # down


def swiglu(p: Dict, x: Array) -> Array:
    h = jax.nn.silu(shard_hint(jnp.einsum("bsd,df->bsf", x, p["wi"]), "b.m"))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wg"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def init_gelu_mlp(fac: ParamFactory, pre: str, cfg: ModelConfig) -> None:
    d, f = cfg.d_model, cfg.d_ff
    fs = cfg.shard(f)
    fac.param(f"{pre}.wi", (d, f), P(None, fs), fan_in=d)
    fac.param(f"{pre}.wo", (f, d), P(fs, None), fan_in=f)


def gelu_mlp(p: Dict, x: Array) -> Array:
    h = jax.nn.gelu(shard_hint(jnp.einsum("bsd,df->bsf", x, p["wi"]), "b.m"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
