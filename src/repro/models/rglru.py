"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
  r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
  log a_t = -c * softplus(Lambda) * r_t          (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses an associative scan over S (log-space first-order
recurrence); decode is the O(1) update.  The block follows RecurrentGemma:
x -> [gelu gate branch] * [conv1d -> RG-LRU branch] -> out proj.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, ParamFactory, shard_hint

Array = jax.Array
_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru_width or cfg.d_model


def init_rglru(fac: ParamFactory, pre: str, cfg: ModelConfig) -> None:
    d, w = cfg.d_model, _width(cfg)
    ws = cfg.shard(w)
    fac.param(f"{pre}.in_x", (d, w), P(None, ws), fan_in=d)    # recurrent branch
    fac.param(f"{pre}.in_gate", (d, w), P(None, ws), fan_in=d) # gelu gate branch
    fac.param(f"{pre}.conv_w", (4, w), P(None, ws), fan_in=4)
    fac.param(f"{pre}.conv_b", (w,), P(ws), init="zeros")
    fac.param(f"{pre}.w_a", (w, w), P(None, ws), fan_in=w)
    fac.param(f"{pre}.b_a", (w,), P(ws), init="zeros")
    fac.param(f"{pre}.w_i", (w, w), P(None, ws), fan_in=w)
    fac.param(f"{pre}.b_i", (w,), P(ws), init="zeros")
    fac.param(f"{pre}.lam", (w,), P(ws), init="ones")          # Lambda > 0
    fac.param(f"{pre}.out", (w, d), P(ws, None), fan_in=w)


def _gates(p: Dict, x: Array):
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", x, p["w_i"]) + p["b_i"])
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a**2, 1e-12))
    return a, beta * (i.astype(jnp.float32) * x.astype(jnp.float32))


def _conv(p: Dict, x: Array) -> Array:
    k = p["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i : i + x.shape[1], :] * p["conv_w"][i] for i in range(k)) + p["conv_b"]


def rglru_full(p: Dict, x: Array, cfg: ModelConfig) -> Array:
    """[B,S,d] -> [B,S,d] via associative scan over S."""
    gate = jax.nn.gelu(shard_hint(jnp.einsum("bsd,dw->bsw", x, p["in_gate"]), "b.m"))
    xr = _conv(p, shard_hint(jnp.einsum("bsd,dw->bsw", x, p["in_x"]), "b.m"))
    a, b = _gates(p, xr)                                      # [B,S,W] f32
    a, b = shard_hint(a, "b.m"), shard_hint(b, "b.m")

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate)
    return jnp.einsum("bsw,wd->bsd", y, p["out"])


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    w = _width(cfg)
    return dict(
        conv=jnp.zeros((batch, 3, w), dtype),
        h=jnp.zeros((batch, w), jnp.float32),
    )


def rglru_decode_step(p: Dict, x1: Array, state: Dict, cfg: ModelConfig
                      ) -> Tuple[Array, Dict]:
    """One-token update.  x1 [B,1,d]."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x1, p["in_gate"]))[:, 0]
    xr1 = jnp.einsum("bsd,dw->bsw", x1, p["in_x"])[:, 0]       # [B,W]
    window = jnp.concatenate([state["conv"], xr1[:, None]], axis=1)
    xr = jnp.sum(window * p["conv_w"], axis=1) + p["conv_b"]
    a, b = _gates(p, xr)
    h = a * state["h"] + b
    y = (h.astype(x1.dtype) * gate)
    out = jnp.einsum("bw,wd->bd", y, p["out"])[:, None]
    return out, dict(conv=window[:, 1:], h=h)
