"""Decoder-only LM assembly: scan-over-layers, heterogeneous block patterns,
train / prefill / decode entry points.

A "super-block" is one repeat of cfg.block_pattern (e.g. RecurrentGemma's
(rglru, rglru, local_attn)); n_layers // len(pattern) repeats are scanned with
stacked params (compact HLO, fast multi-device compiles), the remainder runs
as unscanned tail blocks.  Block kinds:

  attn       causal self-attention (GQA, or MLA when cfg.mla) + SwiGLU
  attn_moe   causal self-attention + MoE FFN (+ shared experts)
  local_attn sliding-window self-attention (cfg.local_window) + SwiGLU
  ssm        Mamba-2 SSD mixer (no separate FFN, per the paper)
  rglru      RG-LRU recurrent mixer + SwiGLU

`constrain` is an optional activation-sharding hook (identity by default); the
launcher passes `with_sharding_constraint(.., P("data", "model", None))` to get
sequence-parallel residual streams on the production mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as ATT
from repro.models import ffn as FFN
from repro.models import moe as MOE
from repro.models import rglru as RGL
from repro.models import ssm as SSM
from repro.models.common import (
    ModelConfig,
    ParamFactory,
    maybe_map,
    maybe_scan,
    rms_norm,
    softmax_xent,
    stack_layer_params,
)

Array = jax.Array
Identity = lambda x: x  # noqa: E731


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_subblock(fac: ParamFactory, pre: str, kind: str, cfg: ModelConfig) -> None:
    d = cfg.d_model
    fac.param(f"{pre}.ln1", (d,), P(None), init="zeros")
    if kind in ("attn", "attn_moe", "local_attn"):
        if cfg.mla is not None and kind != "local_attn":
            ATT.init_mla(fac, f"{pre}.attn", cfg)
        else:
            ATT.init_gqa(fac, f"{pre}.attn", cfg)
        fac.param(f"{pre}.ln2", (d,), P(None), init="zeros")
        if kind == "attn_moe":
            MOE.init_moe(fac, f"{pre}.ffn", cfg)
        else:
            FFN.init_swiglu(fac, f"{pre}.ffn", cfg)
    elif kind == "ssm":
        SSM.init_ssm(fac, f"{pre}.mixer", cfg)
    elif kind == "rglru":
        RGL.init_rglru(fac, f"{pre}.mixer", cfg)
        fac.param(f"{pre}.ln2", (d,), P(None), init="zeros")
        FFN.init_swiglu(fac, f"{pre}.ffn", cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")


def _init_superblock(key: Array, cfg: ModelConfig, shape_only: bool = False):
    fac = ParamFactory(key, dtype=cfg.dtype, shape_only=shape_only)
    for i, kind in enumerate(cfg.block_pattern):
        _init_subblock(fac, f"b{i}", kind, cfg)
    return fac.collect()


def layer_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_scanned_repeats, n_tail_blocks)."""
    k = len(cfg.block_pattern)
    return cfg.n_layers // k, cfg.n_layers % k


def init_lm(key: Array, cfg: ModelConfig, shape_only: bool = False):
    """Returns (params, specs).  shape_only=True -> ShapeDtypeStruct leaves
    (allocation-free; the dry-run path for 236B+ configs)."""
    k_emb, k_blocks, k_tail, k_head = jax.random.split(key, 4)
    fac = ParamFactory(k_emb, dtype=cfg.dtype, shape_only=shape_only)
    vp, d = cfg.padded_vocab, cfg.d_model
    fac.param("embed", (vp, d), P(cfg.shard(vp), None), fan_in=d)
    fac.param("final_norm", (d,), P(None), init="zeros")
    if not cfg.tie_embeddings:
        fac.param("lm_head", (d, vp), P(None, cfg.shard(vp)), fan_in=d)
    if cfg.frontend is not None:
        fd = cfg.frontend.feature_dim
        fac.param("projector.w1", (fd, d), P(None, cfg.shard(d)), fan_in=fd)
        fac.param("projector.b1", (d,), P(None), init="zeros")
        fac.param("projector.w2", (d, d), P(None, cfg.shard(d)), fan_in=d)
        fac.param("projector.b2", (d,), P(None), init="zeros")
    params, specs = fac.collect()

    n_rep, n_tail = layer_counts(cfg)
    if n_rep:
        bl, bl_specs = stack_layer_params(
            lambda k: _init_superblock(k, cfg, shape_only), k_blocks, n_rep
        )
        params["blocks"], specs["blocks"] = bl, bl_specs
    for t in range(n_tail):
        fac_t = ParamFactory(jax.random.fold_in(k_tail, t), dtype=cfg.dtype,
                             shape_only=shape_only)
        _init_subblock(fac_t, "b0", cfg.block_pattern[t], cfg)
        tp, ts = fac_t.collect()
        params[f"tail{t}"], specs[f"tail{t}"] = tp, ts
    return params, specs


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_subblock(kind: str, p: Dict, x: Array, positions: Array,
                    cfg: ModelConfig, window: Optional[int],
                    constrain: Callable) -> Tuple[Array, Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "attn_moe", "local_attn"):
        w = cfg.local_window if kind == "local_attn" else window
        if cfg.mla is not None and kind != "local_attn":
            h = ATT.mla_full(p["attn"], h, cfg, positions, window=w)
        else:
            h = ATT.gqa_full(p["attn"], h, cfg, positions, window=w)
        x = constrain(x + h)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "attn_moe":
            y, aux = MOE.moe_ffn(p["ffn"], h2, cfg)
        else:
            y = FFN.swiglu(p["ffn"], h2)
        x = constrain(x + y)
    elif kind == "ssm":
        x = constrain(x + SSM.ssd_full(p["mixer"], h, cfg))
    elif kind == "rglru":
        x = constrain(x + RGL.rglru_full(p["mixer"], h, cfg))
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = constrain(x + FFN.swiglu(p["ffn"], h2))
    return x, aux


def _apply_superblock(p: Dict, x: Array, positions: Array, cfg: ModelConfig,
                      window: Optional[int], constrain: Callable):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.block_pattern):
        x, a = _apply_subblock(kind, p[f"b{i}"], x, positions, cfg, window, constrain)
        aux = aux + a
    return x, aux


def forward_hidden(params: Dict, x: Array, positions: Array, cfg: ModelConfig,
                   window: Optional[int] = None,
                   constrain: Callable = Identity) -> Tuple[Array, Array]:
    """Embedded inputs [B,S,d] -> final hidden [B,S,d]; returns (h, aux_loss)."""
    n_rep, n_tail = layer_counts(cfg)
    window = window if window is not None else cfg.window
    block_fn = functools.partial(
        _apply_superblock, cfg=cfg, window=window, constrain=constrain
    )
    if cfg.remat:
        block_fn = jax.checkpoint(block_fn)

    aux = jnp.zeros((), jnp.float32)
    if n_rep:
        def body(carry, bp):
            xx, ax = carry
            xx, a = block_fn(bp, xx, positions)
            return (xx, ax + a), None

        (x, aux), _ = maybe_scan(body, (x, aux), params["blocks"],
                                 cfg.unroll_for_analysis)
    for t in range(n_tail):
        kind = cfg.block_pattern[t]
        sub_fn = functools.partial(
            _apply_subblock, kind, cfg=cfg, window=window, constrain=constrain
        )
        if cfg.remat:
            sub_fn = jax.checkpoint(sub_fn)
        x, a = sub_fn(params[f"tail{t}"]["b0"], x, positions)
        aux = aux + a
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def embed_tokens(params: Dict, tokens: Array, cfg: ModelConfig) -> Array:
    return params["embed"][tokens]


def logits_from_hidden(params: Dict, h: Array, cfg: ModelConfig,
                       constrain_logits: Callable = Identity) -> Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain_logits(jnp.einsum("bsd,dv->bsv", h, head))


def hidden_for_batch(params: Dict, tokens: Array, cfg: ModelConfig,
                     window: Optional[int] = None,
                     embeds_prefix: Optional[Array] = None,
                     constrain: Callable = Identity) -> Tuple[Array, Array]:
    """tokens [B,S] (+ optional projected prefix embeddings) -> final hidden
    over the token region [B,S,d] + MoE aux."""
    x = embed_tokens(params, tokens, cfg)
    npfx = 0
    if embeds_prefix is not None:
        pr = params["projector"]
        e = jnp.einsum("bpf,fd->bpd", embeds_prefix.astype(cfg.dtype), pr["w1"]) + pr["b1"]
        e = jnp.einsum("bpd,de->bpe", jax.nn.gelu(e), pr["w2"]) + pr["b2"]
        x = jnp.concatenate([e, x], axis=1)
        npfx = e.shape[1]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h, aux = forward_hidden(params, x, positions, cfg, window, constrain)
    return h[:, npfx:], aux


def forward(params: Dict, tokens: Array, cfg: ModelConfig,
            window: Optional[int] = None,
            embeds_prefix: Optional[Array] = None,
            constrain: Callable = Identity,
            constrain_logits: Callable = Identity) -> Tuple[Array, Array]:
    """tokens [B,S] -> (logits [B,S,Vp], aux).  If `embeds_prefix` [B,P,feat]
    is given (VLM/audio stub features), it is projected and prepended; logits
    cover the token region only."""
    h, aux = hidden_for_batch(params, tokens, cfg, window, embeds_prefix,
                              constrain)
    return logits_from_hidden(params, h, cfg, constrain_logits), aux


def chunked_ce(params: Dict, h: Array, labels: Array, cfg: ModelConfig,
               constrain_logits: Callable = Identity) -> Array:
    """Per-position CE [B,S] from hidden states, lm_head applied in
    cfg.lm_head_chunk-position slices so the [B,S,vocab] tensor never
    materializes (163k-vocab configs would need >100 GB/device)."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    b, s, d = h.shape
    ck = min(cfg.lm_head_chunk, s)

    @jax.checkpoint
    def one(args):
        # rematerialized: saving per-chunk logits for backward would stack
        # [n_chunks, B, ck, vocab] f32 residuals
        hc, lc = args
        logits = constrain_logits(jnp.einsum("bsd,dv->bsv", hc, head))
        return softmax_xent(logits, lc, cfg.vocab_size)

    if s <= ck:
        return one((h, labels))
    n, rem = divmod(s, ck)
    hc = h[:, : n * ck].reshape(b, n, ck, d).swapaxes(0, 1)
    lc = labels[:, : n * ck].reshape(b, n, ck).swapaxes(0, 1)
    ce = maybe_map(one, (hc, lc), cfg.unroll_for_analysis)  # [n,B,ck]
    ce = ce.swapaxes(0, 1).reshape(b, n * ck)
    if rem:
        ce_tail = one((h[:, n * ck:], labels[:, n * ck:]))
        ce = jnp.concatenate([ce, ce_tail], axis=1)
    return ce


def lm_per_example_loss(params: Dict, batch: Dict, cfg: ModelConfig,
                        window: Optional[int] = None,
                        constrain: Callable = Identity,
                        constrain_logits: Callable = Identity):
    """Per-sequence mean next-token CE [B] + MoE aux scalar.  The FL layer
    needs per-example losses so per-worker losses can be weighted by the
    round's received coefficients (the OTA sum via one backward pass)."""
    tokens = batch["tokens"]
    h, aux = hidden_for_batch(
        params, tokens[:, :-1], cfg, window=window,
        embeds_prefix=batch.get("embeds_prefix"), constrain=constrain,
    )
    ce = chunked_ce(params, h, tokens[:, 1:], cfg, constrain_logits)  # [B,S-1]
    return jnp.mean(ce, axis=-1), aux


def lm_loss(params: Dict, batch: Dict, cfg: ModelConfig,
            window: Optional[int] = None,
            constrain: Callable = Identity,
            constrain_logits: Callable = Identity) -> Array:
    """Next-token CE (+ MoE aux).  batch: tokens [B,S] (+ optional
    embeds_prefix); labels are tokens shifted left."""
    per_ex, aux = lm_per_example_loss(
        params, batch, cfg, window=window,
        constrain=constrain, constrain_logits=constrain_logits,
    )
    moe_coef = cfg.moe.router_aux_coef if cfg.moe else 0.0
    return jnp.mean(per_ex) + moe_coef * aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _init_subblock_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                         dtype) -> Dict:
    if kind in ("attn", "attn_moe"):
        if cfg.mla is not None:
            return ATT.init_mla_cache(cfg, batch, max_len, dtype)
        return ATT.init_cache(cfg, batch, max_len, cfg.window, dtype)
    if kind == "local_attn":
        return ATT.init_cache(cfg, batch, max_len, cfg.local_window, dtype)
    if kind == "ssm":
        return SSM.init_ssm_state(cfg, batch, dtype)
    if kind == "rglru":
        return RGL.init_rglru_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                window: Optional[int] = None) -> Dict:
    """Stacked decode caches.  `window` overrides cfg.window for attn blocks
    (the long-context SWA variant)."""
    caches: Dict[str, Any] = {}
    n_rep, n_tail = layer_counts(cfg)
    w_attn = window if window is not None else cfg.window

    def one(kind):
        if kind in ("attn", "attn_moe") and cfg.mla is None:
            return ATT.init_cache(cfg, batch, max_len, w_attn, cfg.dtype)
        return _init_subblock_cache(kind, cfg, batch, max_len, cfg.dtype)

    if n_rep:
        per = {f"b{i}": one(k) for i, k in enumerate(cfg.block_pattern)}
        caches["blocks"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape), per
        )
    for t in range(n_tail):
        caches[f"tail{t}"] = {"b0": one(cfg.block_pattern[t])}
    return caches


def _decode_subblock(kind: str, p: Dict, cache: Dict, x1: Array, pos: Array,
                     cfg: ModelConfig, window: Optional[int]):
    h = rms_norm(x1, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "attn_moe", "local_attn"):
        w = cfg.local_window if kind == "local_attn" else window
        if cfg.mla is not None and kind != "local_attn":
            h, cache = ATT.mla_decode_step(p["attn"], h, cache, pos, cfg)
        else:
            h, cache = ATT.decode_step(p["attn"], h, cache, pos, cfg, window=w)
        x1 = x1 + h
        h2 = rms_norm(x1, p["ln2"], cfg.norm_eps)
        if kind == "attn_moe":
            y, _ = MOE.moe_ffn(p["ffn"], h2, cfg)
        else:
            y = FFN.swiglu(p["ffn"], h2)
        x1 = x1 + y
    elif kind == "ssm":
        y, cache = SSM.ssd_decode_step(p["mixer"], h, cache, cfg)
        x1 = x1 + y
    elif kind == "rglru":
        y, cache = RGL.rglru_decode_step(p["mixer"], h, cache, cfg)
        x1 = x1 + y
        h2 = rms_norm(x1, p["ln2"], cfg.norm_eps)
        x1 = x1 + FFN.swiglu(p["ffn"], h2)
    return x1, cache


def decode_step(params: Dict, caches: Dict, tokens1: Array, pos: Array,
                cfg: ModelConfig, window: Optional[int] = None,
                constrain_logits: Callable = Identity):
    """One decode step.  tokens1 [B,1] int32, pos scalar int32 (0-based index
    of the new token).  Returns (logits [B,1,Vp], new_caches)."""
    x = embed_tokens(params, tokens1, cfg)
    window = window if window is not None else cfg.window
    n_rep, n_tail = layer_counts(cfg)
    new_caches: Dict[str, Any] = {}

    def sb(p_sb, c_sb, x1):
        c_new = {}
        for i, kind in enumerate(cfg.block_pattern):
            x1, c_new[f"b{i}"] = _decode_subblock(
                kind, p_sb[f"b{i}"], c_sb[f"b{i}"], x1, pos, cfg, window
            )
        return x1, c_new

    if n_rep:
        def body(x1, inp):
            p_sb, c_sb = inp
            x1, c_new = sb(p_sb, c_sb, x1)
            return x1, c_new

        x, new_caches["blocks"] = maybe_scan(
            body, x, (params["blocks"], caches["blocks"]),
            cfg.unroll_for_analysis
        )
    for t in range(n_tail):
        kind = cfg.block_pattern[t]
        x, c = _decode_subblock(
            kind, params[f"tail{t}"]["b0"], caches[f"tail{t}"]["b0"], x, pos,
            cfg, window,
        )
        new_caches[f"tail{t}"] = {"b0": c}
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, h, cfg, constrain_logits), new_caches
