"""Attention: GQA (full / sliding-window / decode), qk-norm, MLA (DeepSeek-V2).

Weight layout: wq [d, H, hd], wk/wv [d, KV, hd], wo [H, hd, d].  Sharding
prefers the head dim on "model"; falls back to the d_model contraction dim for
head counts that do not divide the mesh (StarCoder2 24H, Llama-4 40H).

Decode provides two paths:
  * `decode_step` — pjit-friendly, cache sharded on batch/kv-heads.
  * `decode_local_partial` + `combine_partials` — flash-decoding style
    partial-softmax pieces for *sequence-sharded* KV caches (used under
    shard_map for long_500k and non-divisible-head archs; the combine is a
    pmax/psum over the sharded axes = the collective the roofline sees).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    ModelConfig,
    ParamFactory,
    apply_rope,
    make_causal_mask,
    rms_norm,
    shard_hint,
)

Array = jax.Array


def _wspec(cfg: ModelConfig, shape, prefer: int) -> P:
    """Shard dim `prefer` on "model" if legal, else first other legal dim."""
    order = [prefer] + [i for i in range(len(shape)) if i != prefer]
    for i in order:
        if cfg.shard(shape[i]):
            return P(*[("model" if j == i else None) for j in range(len(shape))])
    return P(*([None] * len(shape)))


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(fac: ParamFactory, pre: str, cfg: ModelConfig) -> None:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    fac.param(f"{pre}.wq", (d, h, hd), _wspec(cfg, (d, h, hd), 1), fan_in=d)
    fac.param(f"{pre}.wk", (d, kv, hd), _wspec(cfg, (d, kv, hd), 1), fan_in=d)
    fac.param(f"{pre}.wv", (d, kv, hd), _wspec(cfg, (d, kv, hd), 1), fan_in=d)
    fac.param(f"{pre}.wo", (h, hd, d), _wspec(cfg, (h, hd, d), 0), fan_in=h * hd)
    if cfg.qk_norm:
        fac.param(f"{pre}.q_norm", (hd,), P(None), init="zeros")
        fac.param(f"{pre}.k_norm", (hd,), P(None), init="zeros")


def _qkv(p: Dict, x: Array, cfg: ModelConfig, positions: Optional[Array],
         rope: bool = True) -> Tuple[Array, Array, Array]:
    q = shard_hint(jnp.einsum("bsd,dhk->bshk", x, p["wq"]), "b.m.")
    k = shard_hint(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), "b.m.")
    v = shard_hint(jnp.einsum("bsd,dhk->bshk", x, p["wv"]), "b.m.")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope and positions is not None:
        # positions [B,S] -> rotate per head (head axis broadcast inside)
        q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    return q, k, v


def _gqa_core(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """q [B,Sq,H,hd], k/v [B,Sk,KV,hd] -> [B,Sq,H,hd]; softmax in f32."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k).astype(jnp.float32)
    scores = shard_hint(scores / jnp.sqrt(jnp.float32(hd)), "bm...")
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs, v)
    return out.reshape(b, sq, h, hd)


Q_CHUNK = 1024  # query-block size for memory-bounded full attention


def _chunked_attn(q: Array, k: Array, v: Array, causal: bool,
                  window: Optional[int], q_chunk: int = Q_CHUNK,
                  unroll: bool = False) -> Array:
    """Query-chunked attention: scores never exceed [B,H,q_chunk,Sk] per step
    (keeps the 32k-prefill score tensor off the memory peak; lax.map = scan,
    so it composes with remat/AD)."""
    from repro.models.common import maybe_map

    b, sq, h, hd = q.shape
    if sq <= q_chunk:
        mask = make_causal_mask(sq, sq, 0, window)[None, None, None] if causal else None
        return _gqa_core(q, k, v, mask)
    assert sq % q_chunk == 0, (sq, q_chunk)
    n = sq // q_chunk
    qc = q.reshape(b, n, q_chunk, h, hd).swapaxes(0, 1)      # [n,B,qc,H,hd]
    offs = jnp.arange(n) * q_chunk

    def one(args):
        qi, off = args
        mask = (make_causal_mask(q_chunk, sq, off, window)[None, None, None]
                if causal else None)
        return _gqa_core(qi, k, v, mask)

    out = maybe_map(one, (qc, offs), unroll)                 # [n,B,qc,H,hd]
    return out.swapaxes(0, 1).reshape(b, sq, h, hd)


def gqa_full(p: Dict, x: Array, cfg: ModelConfig, positions: Array,
             window: Optional[int] = None, causal: bool = True) -> Array:
    """Self-attention over a full [B,S,d] block (train / prefill)."""
    q, k, v = _qkv(p, x, cfg, positions)
    out = _chunked_attn(q, k, v, causal, window,
                        unroll=cfg.unroll_for_analysis)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention(p: Dict, x: Array, enc_kv: Tuple[Array, Array],
                    cfg: ModelConfig) -> Array:
    """Decoder cross-attention; enc_kv precomputed ([B,Se,KV,hd] x2), no RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = enc_kv
    out = _chunked_attn(q, k, v, causal=False, window=None,
                        unroll=cfg.unroll_for_analysis)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_kv(p: Dict, enc_out: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# --- decode -----------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, window: Optional[int],
               dtype) -> Dict[str, Array]:
    """KV cache for one attention layer.  Ring-buffered if windowed; int8
    storage (+ per-position/head f16 absmax scales) when cfg.kv_cache_dtype
    is "int8" — decode is cache-bandwidth-bound, so this halves the dominant
    memory roofline term at <0.5% logit error (tests/test_kv_quant.py)."""
    slots = min(max_len, window) if window else max_len
    kv, hd = cfg.n_kv_heads, cfg.hd
    if cfg.kv_cache_dtype == "int8":
        return dict(
            k=jnp.zeros((batch, slots, kv, hd), jnp.int8),
            v=jnp.zeros((batch, slots, kv, hd), jnp.int8),
            k_scale=jnp.zeros((batch, slots, kv), jnp.float16),
            v_scale=jnp.zeros((batch, slots, kv), jnp.float16),
        )
    return dict(
        k=jnp.zeros((batch, slots, kv, hd), dtype),
        v=jnp.zeros((batch, slots, kv, hd), dtype),
    )


def _quantize_kv(x: Array):
    """[B,1,KV,hd] -> (int8 values, f16 absmax scales [B,1,KV])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q: Array, scale: Array, dtype) -> Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def decode_step(p: Dict, x1: Array, cache: Dict, pos: Array, cfg: ModelConfig,
                window: Optional[int] = None) -> Tuple[Array, Dict]:
    """One-token decode.  x1 [B,1,d]; pos scalar (current index); cache len S.

    Windowed caches are ring buffers (slot = pos % window); positions are
    reconstructed for masking so RoPE/causality stay exact.
    """
    b = x1.shape[0]
    q, k1, v1 = _qkv(p, x1, cfg, jnp.full((b, 1), pos))
    slots = cache["k"].shape[1]
    slot = (pos % slots).astype(jnp.int32) if window else pos.astype(jnp.int32)
    quant = cfg.kv_cache_dtype == "int8"
    new_cache = {}
    if quant:
        k1q, k1s = _quantize_kv(k1)
        v1q, v1s = _quantize_kv(v1)
        new_cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k1q, (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v1q, (0, slot, 0, 0))
        new_cache["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], k1s, (0, slot, 0))
        new_cache["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], v1s, (0, slot, 0))
        ck = _dequantize_kv(new_cache["k"], new_cache["k_scale"], k1.dtype)
        cv = _dequantize_kv(new_cache["v"], new_cache["v_scale"], v1.dtype)
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k1, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v1, (0, slot, 0, 0))
        new_cache = dict(k=ck, v=cv)
    # true position of each slot (for causal/window masking)
    idx = jnp.arange(slots)
    if window:
        n_wraps = (pos + 1 + slots - 1 - idx) // slots
        kpos = idx + (n_wraps) * slots - slots  # position last written to slot
        kpos = jnp.where(kpos > pos, kpos - slots, kpos)
    else:
        kpos = idx
    valid = (kpos <= pos) & (kpos >= 0)
    if window:
        valid &= kpos > pos - window
    mask = valid[None, None, None, None, :]
    out = _gqa_core(q, ck, cv, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def decode_local_partial(q: Array, k_loc: Array, v_loc: Array,
                         valid: Array) -> Tuple[Array, Array, Array]:
    """Partial flash-decode on a local KV shard.

    q [B,H,hd]; k_loc/v_loc [B,S_loc,KV,hd]; valid [B,S_loc] bool.
    Returns (m [B,H], l [B,H], acc [B,H,hd]) partial softmax stats.
    """
    b, h, hd = q.shape
    kvh = k_loc.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_loc).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)                                   # [B,KV,G]
    e = jnp.exp(s - m[..., None])
    l = jnp.sum(e, axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", e.astype(v_loc.dtype), v_loc)
    return (m.reshape(b, h), l.reshape(b, h),
            acc.reshape(b, h, hd).astype(jnp.float32))


def combine_partials(m: Array, l: Array, acc: Array, axis_names) -> Array:
    """psum/pmax combine of partial softmax stats over mesh axes -> [B,H,hd]."""
    mg = jax.lax.pmax(m, axis_names)
    scale = jnp.exp(m - mg)
    lg = jax.lax.psum(l * scale, axis_names)
    accg = jax.lax.psum(acc * scale[..., None], axis_names)
    return accg / jnp.maximum(lg, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(fac: ParamFactory, pre: str, cfg: ModelConfig) -> None:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    fac.param(f"{pre}.wq_a", (d, m.q_lora), _wspec(cfg, (d, m.q_lora), 1), fan_in=d)
    fac.param(f"{pre}.q_norm", (m.q_lora,), P(None), init="zeros")
    fac.param(f"{pre}.wq_b", (m.q_lora, h, qd), _wspec(cfg, (m.q_lora, h, qd), 1),
              fan_in=m.q_lora)
    fac.param(f"{pre}.wkv_a", (d, m.kv_lora + m.qk_rope_dim),
              P(None, None), fan_in=d)
    fac.param(f"{pre}.kv_norm", (m.kv_lora,), P(None), init="zeros")
    fac.param(f"{pre}.wk_b", (m.kv_lora, h, m.qk_nope_dim),
              _wspec(cfg, (m.kv_lora, h, m.qk_nope_dim), 1), fan_in=m.kv_lora)
    fac.param(f"{pre}.wv_b", (m.kv_lora, h, m.v_dim),
              _wspec(cfg, (m.kv_lora, h, m.v_dim), 1), fan_in=m.kv_lora)
    fac.param(f"{pre}.wo", (h, m.v_dim, d), _wspec(cfg, (h, m.v_dim, d), 0),
              fan_in=h * m.v_dim)


def _mla_q(p: Dict, x: Array, cfg: ModelConfig, positions: Array):
    m = cfg.mla
    cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = shard_hint(jnp.einsum("bsq,qhk->bshk", cq, p["wq_b"]), "b.m.")
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None, :],
                        cfg.rope_theta).swapaxes(1, 2)
    return q_nope, q_rope


def _mla_ckv(p: Dict, x: Array, cfg: ModelConfig, positions: Array):
    m = cfg.mla
    kv_a = jnp.einsum("bsd,de->bse", x, p["wkv_a"])
    c_kv = rms_norm(kv_a[..., : m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., m.kv_lora :], positions, cfg.rope_theta)
    return c_kv, k_rope  # [B,S,kv_lora], [B,S,rope]


def mla_full(p: Dict, x: Array, cfg: ModelConfig, positions: Array,
             window: Optional[int] = None) -> Array:
    """Train/prefill MLA: materialize per-head K/V from the latent (cheap at
    these lengths); decode uses the absorbed form instead."""
    m = cfg.mla
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_ckv(p, x, cfg, positions)
    k_nope = shard_hint(jnp.einsum("bse,ehk->bshk", c_kv, p["wk_b"]), "b.m.")
    v = shard_hint(jnp.einsum("bse,ehk->bshk", c_kv, p["wv_b"]), "b.m.")
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))
    sq = x.shape[1]
    qc = Q_CHUNK
    n = max(sq // qc, 1)
    if sq % qc or n == 1:
        n, qc = 1, sq

    def one(args):
        qn, qr, off = args
        s = (jnp.einsum("bqhk,bshk->bhqs", qn, k_nope)
             + jnp.einsum("bqhk,bsk->bhqs", qr, k_rope)).astype(jnp.float32) * scale
        s = shard_hint(s, "bm..")
        mask = make_causal_mask(qc, sq, off, window)[None, None]
        s = jnp.where(mask, s, -1e30)
        probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshk->bqhk", probs, v)

    if n == 1:
        out = one((q_nope, q_rope, jnp.int32(0)))
    else:
        from repro.models.common import maybe_map

        b, _, h, dn = q_nope.shape
        qn = q_nope.reshape(b, n, qc, h, dn).swapaxes(0, 1)
        qr = q_rope.reshape(b, n, qc, h, -1).swapaxes(0, 1)
        out = maybe_map(one, (qn, qr, jnp.arange(n) * qc),
                        cfg.unroll_for_analysis)
        out = out.swapaxes(0, 1).reshape(b, sq, h, -1)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    return dict(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora), dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    )


def mla_decode_step(p: Dict, x1: Array, cache: Dict, pos: Array,
                    cfg: ModelConfig) -> Tuple[Array, Dict]:
    """Absorbed-form MLA decode: everything stays in the kv_lora latent, so the
    per-token cache cost is kv_lora + rope bytes (MLA's raison d'etre)."""
    m = cfg.mla
    b = x1.shape[0]
    pos_b = jnp.full((b, 1), pos)
    q_nope, q_rope = _mla_q(p, x1, cfg, pos_b)          # [B,1,H,*]
    c1, r1 = _mla_ckv(p, x1, cfg, pos_b)                # [B,1,lora],[B,1,rope]
    ck = jax.lax.dynamic_update_slice(cache["c_kv"], c1, (0, pos.astype(jnp.int32), 0))
    cr = jax.lax.dynamic_update_slice(cache["k_rope"], r1, (0, pos.astype(jnp.int32), 0))
    # absorb W_uk into q: q_eff [B,H,lora]
    q_eff = jnp.einsum("bhk,ehk->bhe", q_nope[:, 0], p["wk_b"])
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))
    s = (jnp.einsum("bhe,bse->bhs", q_eff, ck)
         + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], cr)).astype(jnp.float32) * scale
    valid = jnp.arange(ck.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1).astype(ck.dtype)
    ctx = jnp.einsum("bhs,bse->bhe", probs, ck)          # [B,H,lora]
    out = jnp.einsum("bhe,ehk->bhk", ctx, p["wv_b"])     # [B,H,v]
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return y, dict(c_kv=ck, k_rope=cr)
