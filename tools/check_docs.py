"""Docs health check: intra-repo markdown links + runnable code snippets.

The docs/ tree and README are part of the engine's contract surface, so CI
treats them like code (the `docs` job runs this script on every push):

1. **Links.** Every relative markdown link `[text](path)` and
   `[text](path#anchor)` must resolve: the file exists inside the repo, and
   for `.md` targets the `#anchor` matches a heading (GitHub slug rules).
   External links (http/https/mailto) are ignored.  Links that resolve
   outside the repo root (e.g. README's `../../actions/...` CI badge, which
   is a GitHub-web path) are skipped, not failed.
2. **Python snippets.** Every ```python fenced block must at least
   compile (syntax check).  Blocks explicitly marked with an HTML comment
   `<!-- docs-smoke -->` on the line directly above the fence are also
   EXECUTED (with --run-snippets) under `PYTHONPATH=src:. REPRO_SMOKE=1`
   from the repo root — the docs' worked examples cannot silently rot.
3. **Bash snippets.** Not executed, but every `*.py` path token inside a
   ```bash block must exist in the repo — a renamed benchmark script breaks
   the docs build instead of the reader.

Usage:
  PYTHONPATH=src python tools/check_docs.py [--run-snippets] [files...]

With no files, checks README.md and docs/**/*.md from the repo root.
Exits non-zero listing every failure (it does not stop at the first).
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SMOKE_MARK = "<!-- docs-smoke -->"

# [text](target) — excluding images; target split from an optional #anchor.
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading line (enough of the rules for this
    repo: lowercase, drop punctuation except hyphens/spaces, spaces to
    hyphens; markdown emphasis/code markers stripped)."""
    text = heading.strip().lstrip("#").strip()
    # Strip markdown code/emphasis markers but NOT underscores: GitHub keeps
    # them (`sweep_bench.py` slugs to sweep_benchpy), and no heading in this
    # repo uses _underscore emphasis_.
    text = re.sub(r"[`*]", "", text)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # links -> text
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(md_path: str) -> set:
    slugs = set()
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for line in f:
            if line.startswith("```"):
                in_fence = not in_fence
            elif not in_fence and line.lstrip().startswith("#"):
                slugs.add(github_slug(line))
    return slugs


def iter_links(md_text: str):
    """(target, anchor) pairs for every non-external link, fences excluded."""
    in_fence = False
    for line in md_text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, anchor = target.partition("#")
            yield path, anchor


def iter_code_blocks(md_text: str):
    """(lang, code, smoke_marked) for every fenced block."""
    lines = md_text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE_RE.match(lines[i])
        if m:
            lang = m.group(1)
            marked = any(SMOKE_MARK in lines[j] for j in range(max(0, i - 2), i)
                         if lines[j].strip())
            body, i = [], i + 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield lang, "\n".join(body), marked
        i += 1


def check_links(md_path: str) -> list:
    errors = []
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    own_slugs = None
    for path, anchor in iter_links(text):
        rel = os.path.relpath(md_path, REPO_ROOT)
        if path:
            full = os.path.abspath(os.path.join(base, path))
            if not (full == REPO_ROOT
                    or full.startswith(REPO_ROOT + os.sep)):
                continue  # GitHub-web path (e.g. the CI badge); not on disk
            if not os.path.exists(full):
                errors.append(f"{rel}: broken link -> {path}")
                continue
        else:
            full = md_path
        if anchor and full.endswith(".md"):
            if full == md_path:
                if own_slugs is None:
                    own_slugs = heading_slugs(md_path)
                slugs = own_slugs
            else:
                slugs = heading_slugs(full)
            if anchor.lower() not in slugs:
                errors.append(
                    f"{rel}: broken anchor -> {path or '(self)'}#{anchor}")
    return errors


def check_snippets(md_path: str, run: bool) -> list:
    errors = []
    rel = os.path.relpath(md_path, REPO_ROOT)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    for n, (lang, code, marked) in enumerate(iter_code_blocks(text)):
        label = f"{rel} block {n} ({lang or 'plain'})"
        if lang == "python":
            try:
                compile(code, label, "exec")
            except SyntaxError as e:
                errors.append(f"{label}: syntax error: {e}")
                continue
            if marked and run:
                env = dict(os.environ, REPRO_SMOKE="1", JAX_PLATFORMS="cpu")
                env["PYTHONPATH"] = os.pathsep.join(
                    [os.path.join(REPO_ROOT, "src"), REPO_ROOT,
                     env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
                proc = subprocess.run(
                    [sys.executable, "-c", code], cwd=REPO_ROOT, env=env,
                    capture_output=True, text=True)
                if proc.returncode != 0:
                    errors.append(f"{label}: snippet failed "
                                  f"(exit {proc.returncode}):\n"
                                  f"{proc.stderr.strip()[-2000:]}")
        elif lang in ("bash", "sh", "shell"):
            for tok in re.findall(r"[\w./-]+\.py\b", code):
                if not os.path.exists(os.path.join(REPO_ROOT, tok)):
                    errors.append(f"{label}: references missing file {tok}")
    return errors


def default_files() -> list:
    files = [os.path.join(REPO_ROOT, "README.md")]
    docs = os.path.join(REPO_ROOT, "docs")
    for root, _, names in os.walk(docs):
        files += [os.path.join(root, n) for n in sorted(names)
                  if n.endswith(".md")]
    return files


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", help="markdown files "
                    "(default: README.md + docs/**/*.md)")
    ap.add_argument("--run-snippets", action="store_true",
                    help="execute <!-- docs-smoke --> marked python blocks "
                         "(PYTHONPATH=src:. REPRO_SMOKE=1, repo root cwd)")
    args = ap.parse_args(argv)
    files = [os.path.abspath(f) for f in args.files] or default_files()
    errors, checked = [], 0
    for f in files:
        errors += check_links(f)
        errors += check_snippets(f, run=args.run_snippets)
        checked += 1
    if errors:
        print(f"check_docs: {len(errors)} problem(s) in {checked} file(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    mode = "links + snippets (executed)" if args.run_snippets else \
        "links + snippet syntax"
    print(f"check_docs: OK — {checked} file(s), {mode}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
