"""Shared scenario-grid builders for the sweep-engine equivalence suites
(test_sweep_sharded.py, test_sweep_chunked.py): one tiny MLP problem and the
CI/BEV x attacker-count and mixed analog+defense grids, parameterized where
the suites deliberately differ (round count, jamming lane, defense list) so
a change to FLOAConfig/ScenarioCase construction lands in every suite at
once."""
import jax
import jax.numpy as jnp

from repro.core.aggregation import FLOAConfig
from repro.core.attacks import AttackConfig, AttackType, first_n_mask
from repro.core.channel import ChannelConfig
from repro.core.power_control import Policy, PowerConfig
from repro.core.scenario import DefenseSpec
from repro.fl import ScenarioCase
from strategies import regression_batches

U = 4


def tiny_problem(rounds=5, batch=8, d_in=6, d_h=5):
    def loss(params, b):
        pred = jax.nn.relu(b["x"] @ params["w1"]) @ params["w2"]
        return jnp.mean((pred - b["y"]) ** 2)
    k = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(k, (d_in, d_h)),
              "w2": jax.random.normal(k, (d_h, 1))}
    dim = sum(p.size for p in jax.tree_util.tree_leaves(params))
    batches = regression_batches(0, rounds, U * batch, d_in)
    return loss, params, dim, batches


def floa(dim, policy, n_atk, noise=0.05, attack=AttackType.STRONGEST):
    return FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=1.0,
                              noise_std=0.0 if policy == Policy.EF else noise),
        power=PowerConfig(num_workers=U, dim=dim, p_max=1.0, policy=policy),
        attack=AttackConfig(attack=attack if n_atk else AttackType.NONE,
                            byzantine_mask=first_n_mask(U, n_atk)),
    )


def grid_cases(dim, num, jam_lane=False):
    """CI/BEV x attacker-count grid, cycled to `num` lanes (fig-4 style).
    jam_lane=True swaps the last lane for a GAUSSIAN-jamming one so every
    RNG stream (channel, noise, jam) is exercised."""
    cells = [(pol, n) for n in (0, 1, 2, 3) for pol in (Policy.CI, Policy.BEV)]
    n_grid = num - 1 if jam_lane else num
    cases = [ScenarioCase(f"{cells[i % 8][0].value}@N{cells[i % 8][1]}#{i}",
                          floa(dim, cells[i % 8][0], cells[i % 8][1]),
                          0.05, seed=100 + i)
             for i in range(n_grid)]
    if jam_lane:
        cases.append(ScenarioCase("jam", floa(dim, Policy.BEV, 2,
                                              attack=AttackType.GAUSSIAN),
                                  0.05, seed=99))
    return cases


DEFENSES = (
    DefenseSpec(name="mean"),
    DefenseSpec(name="median"),
    DefenseSpec(name="trimmed_mean", trim=1),
    DefenseSpec(name="krum", num_byzantine=1),
    DefenseSpec(name="multi_krum", num_byzantine=1, multi=2),
    DefenseSpec(name="geometric_median"),
)


def defense_grid_cases(dim, num, defenses=DEFENSES):
    """Mixed analog + digital lanes cycled to `num` (the showdown grid in
    miniature): lanes 0/1 of each period are FLOA BEV/CI, the rest walk
    `defenses`."""
    period = 2 + len(defenses)
    cases = []
    for i in range(num):
        j, n_atk = i % period, (i // period) % 3
        if j < 2:
            pol = (Policy.BEV, Policy.CI)[j]
            cases.append(ScenarioCase(f"{pol.value}@N{n_atk}#{i}",
                                      floa(dim, pol, n_atk), 0.05,
                                      seed=200 + i))
        else:
            spec = defenses[j - 2]
            cases.append(ScenarioCase(f"{spec.name}@N{n_atk}#{i}",
                                      floa(dim, Policy.EF, n_atk, 0.0), 0.05,
                                      seed=200 + i, defense=spec))
    return cases
