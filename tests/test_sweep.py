"""Sweep-engine contracts: batched kernel vs einsum oracle, branchless
scenario coefficients vs the branching dataclass modules, scan-trainer vs
looped FLTrainer bit-for-bit, and vmapped grids vs sequential runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.core import attacks as A
from repro.core import scenario as SC
from repro.core.aggregation import FLOAConfig, batched_floa_combine
from repro.core.attacks import AttackConfig, AttackType, first_n_mask
from repro.core.channel import ChannelConfig, sample_channel_gains
from repro.core.power_control import Policy, PowerConfig, transmit_amplitudes
from repro.data import FederatedSampler
from repro.fl import (ExecutionPlan, FLTrainer, ScenarioCase, SweepEngine,
                      SweepSpec)
from repro.kernels import ops
from strategies import regression_batches, toy_shards

U = 4


# ---------------------------------------------------------------- kernels


@pytest.mark.parametrize("s,u,d", [(1, 4, 512), (3, 10, 2048), (5, 16, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_floa_aggregate_batched_sweep(s, u, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s * u * d), 5)
    coeffs = jax.random.normal(ks[0], (s, u))
    grads = jax.random.normal(ks[1], (s, u, d)).astype(dtype)
    noise = jax.random.normal(ks[2], (s, d)).astype(dtype)
    bias = jax.random.normal(ks[3], (s,))
    eps = jax.random.normal(ks[4], (s,))
    got = ops.floa_aggregate_batched(coeffs, grads, noise, bias, eps,
                                     interpret=True)
    want = ops.floa_aggregate_batched_ref(coeffs, grads, noise, bias, eps)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_batched_ref_matches_per_scenario_unbatched():
    s, u, d = 3, 10, 1000
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    coeffs = jax.random.normal(ks[0], (s, u))
    grads = jax.random.normal(ks[1], (s, u, d))
    noise = jax.random.normal(ks[2], (s, d))
    bias = jax.random.normal(ks[3], (s,))
    eps = jax.random.normal(ks[4], (s,))
    want = jnp.stack([
        ops.floa_aggregate_ref(coeffs[i], grads[i], noise[i], bias[i], eps[i])
        for i in range(s)])
    got = ops.floa_aggregate_batched_ref(coeffs, grads, noise, bias, eps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_floa_combine_kernel_route_matches_ref():
    """aggregation.py's router: kernel (interpret) and einsum paths agree."""
    s, u, d = 2, 6, 4096
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    args = (jax.random.normal(ks[0], (s, u)),
            jax.random.normal(ks[1], (s, u, d)),
            jax.random.normal(ks[2], (s, d)),
            jax.random.normal(ks[3], (s,)),
            jax.random.normal(ks[4], (s,)))
    via_kernel = batched_floa_combine(*args, use_kernel=True, interpret=True)
    via_ref = batched_floa_combine(*args, use_kernel=False)
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(via_ref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------- branchless scenario params


def _floa(policy, attack, n_atk, sigma=(1.0, 0.5, 2.0, 1.5), noise=0.3):
    return FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=sigma, noise_std=noise),
        power=PowerConfig(num_workers=U, dim=1000, p_max=1.0, policy=policy),
        attack=AttackConfig(attack=attack,
                            byzantine_mask=first_n_mask(U, n_atk)),
    )


@pytest.mark.parametrize("policy", [Policy.CI, Policy.BEV,
                                    Policy.TRUNCATED_CI, Policy.EF])
@pytest.mark.parametrize("attack,n_atk", [
    (AttackType.NONE, 0),
    (AttackType.STRONGEST, 2),
    (AttackType.SIGN_FLIP_PROTOCOL_POWER, 2),
    (AttackType.GAUSSIAN, 2),
])
def test_scenario_coefficients_match_dataclass(policy, attack, n_atk):
    """The branchless rewrite agrees with channel/power_control/attacks for
    every policy x attack combination (including the EF early-return)."""
    cfg = _floa(policy, attack, n_atk)
    sp = SC.from_floa(cfg, alpha=0.1)
    key = jax.random.PRNGKey(3)
    h = sample_channel_gains(key, cfg.channel)
    np.testing.assert_array_equal(np.asarray(SC.sample_gains(key, sp)),
                                  np.asarray(h))
    gbar, eps2 = jnp.float32(0.02), jnp.float32(1.7)
    assert float(sp.dim) == cfg.power.dim  # power-accounting D, not model size
    s, bias_w, jam_std, noise_std, dir_w = SC.scenario_coefficients(
        h, sp, gbar, eps2)
    assert float(dir_w) == 0.0  # no directional attack in this grid

    if policy == Policy.EF:
        sign = (jnp.where(cfg.attack.mask(), -1.0, 1.0)
                if attack != AttackType.NONE else jnp.ones((U,)))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sign / U))
        assert float(bias_w) == 0.0 and float(jam_std) == 0.0
        assert float(noise_std) == 0.0
        return

    want_s, want_bias = A.signed_coefficients(
        h, cfg.power, cfg.channel, cfg.attack, gbar, eps2)
    want_jam = A.gaussian_jam_std(h, cfg.power, cfg.attack, eps2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(bias_w), float(want_bias),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(jam_std), float(want_jam),
                               rtol=1e-6, atol=1e-7)
    assert float(noise_std) == np.float32(cfg.channel.noise_std)
    # honest rows equal the power-control amplitudes exactly
    honest = ~np.asarray(cfg.attack.mask())
    want_honest = np.asarray(
        transmit_amplitudes(h, cfg.power, cfg.channel) * h)
    np.testing.assert_allclose(np.asarray(s)[honest], want_honest[honest],
                               rtol=1e-6)


def test_scenario_stack_vmaps():
    """Stacked params + vmapped coefficients == per-scenario calls."""
    cfgs = [_floa(Policy.CI, AttackType.NONE, 0),
            _floa(Policy.BEV, AttackType.STRONGEST, 2),
            _floa(Policy.EF, AttackType.STRONGEST, 1),
            _floa(Policy.BEV, AttackType.GAUSSIAN, 3)]
    sps = [SC.from_floa(c, alpha=0.1) for c in cfgs]
    stacked = SC.stack(tuple(sps))
    h = jax.vmap(SC.sample_gains)(
        jax.random.split(jax.random.PRNGKey(0), len(cfgs)), stacked)
    gbar = jnp.arange(1.0, len(cfgs) + 1.0) * 0.01
    eps2 = jnp.arange(1.0, len(cfgs) + 1.0)
    out = jax.vmap(SC.scenario_coefficients)(h, stacked, gbar, eps2)
    for i, sp in enumerate(sps):
        want = SC.scenario_coefficients(h[i], sp, gbar[i], eps2[i])
        for got_leaf, want_leaf in zip(out, want):
            np.testing.assert_array_equal(np.asarray(got_leaf[i]),
                                          np.asarray(want_leaf))


# ----------------------------------------------------- engine equivalence


def _tiny_problem(rounds=6, batch=8, d_in=6, d_h=5):
    def loss(params, b):
        pred = jax.nn.relu(b["x"] @ params["w1"]) @ params["w2"]
        return jnp.mean((pred - b["y"]) ** 2)
    k = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(k, (d_in, d_h)),
              "w2": jax.random.normal(k, (d_h, 1))}
    dim = sum(p.size for p in jax.tree_util.tree_leaves(params))
    batches = regression_batches(0, rounds, U * batch, d_in)
    return loss, params, dim, batches


def _tiny_floa(dim, policy=Policy.BEV, n_atk=1, noise=0.05,
               attack=AttackType.STRONGEST):
    return FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=1.0,
                              noise_std=0.0 if policy == Policy.EF else noise),
        power=PowerConfig(num_workers=U, dim=dim, p_max=1.0, policy=policy),
        attack=AttackConfig(attack=attack if n_atk else AttackType.NONE,
                            byzantine_mask=first_n_mask(U, n_atk)),
    )


class _Replay:
    """Sampler stand-in that replays a pre-stacked batch dict round by round."""

    def __init__(self, batches):
        self.batches, self.t = batches, 0

    def next_round(self):
        out = {k: v[self.t] for k, v in self.batches.items()}
        self.t += 1
        return out


def test_run_scan_matches_loop_bitwise():
    """FLTrainer.run_scan must replay FLTrainer.run exactly: same keys, same
    batches -> bit-identical params and losses (noise and channel included)."""
    loss, params, dim, batches = _tiny_problem(rounds=7)
    tr = FLTrainer(loss_fn=loss, floa=_tiny_floa(dim), alpha=0.05)
    rounds = batches["x"].shape[0]
    p_loop, logs_loop = tr.run(dict(params), _Replay(batches), rounds,
                               jax.random.PRNGKey(3), eval_every=1)
    p_scan, logs_scan = tr.run_scan(dict(params), batches,
                                    jax.random.PRNGKey(3), eval_every=1)
    for k in params:
        np.testing.assert_array_equal(np.asarray(p_loop[k]),
                                      np.asarray(p_scan[k]))
    assert [l.loss for l in logs_loop] == [l.loss for l in logs_scan]
    assert [l.grad_norm for l in logs_loop] == [l.grad_norm for l in logs_scan]


def test_run_scan_matches_loop_digital_mode():
    loss, params, dim, batches = _tiny_problem(rounds=5)
    tr = FLTrainer(loss_fn=loss, floa=_tiny_floa(dim, policy=Policy.EF),
                   alpha=0.05, mode="digital", defense="median")
    rounds = batches["x"].shape[0]
    p_loop, _ = tr.run(dict(params), _Replay(batches), rounds,
                       jax.random.PRNGKey(2), eval_every=1)
    p_scan, _ = tr.run_scan(dict(params), batches, jax.random.PRNGKey(2),
                            eval_every=1)
    for k in params:
        np.testing.assert_array_equal(np.asarray(p_loop[k]),
                                      np.asarray(p_scan[k]))


def test_vmapped_grid_matches_singles():
    """A 2x2 (policy x attackers) vmapped grid reproduces each scenario's
    single-lane sequential run (tight tolerance: the S=4 and S=1 programs may
    schedule reductions differently, but the math is lane-independent)."""
    loss, params, dim, batches = _tiny_problem(rounds=5)
    cases = [ScenarioCase("ci0", _tiny_floa(dim, Policy.CI, 0), 0.05, seed=1),
             ScenarioCase("ci2", _tiny_floa(dim, Policy.CI, 2), 0.05, seed=2),
             ScenarioCase("bev0", _tiny_floa(dim, Policy.BEV, 0), 0.05, seed=3),
             ScenarioCase("bev2", _tiny_floa(dim, Policy.BEV, 2), 0.05, seed=4)]
    grid = SweepEngine(loss, SweepSpec.build(cases)).run(params, batches)
    for i, case in enumerate(cases):
        single = SweepEngine(loss, SweepSpec.build([case])).run(params, batches)
        np.testing.assert_allclose(grid.loss[i], single.loss[0],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(grid.grad_norm[i], single.grad_norm[0],
                                   rtol=1e-5, atol=1e-6)
        for gleaf, sleaf in zip(jax.tree_util.tree_leaves(grid.params),
                                jax.tree_util.tree_leaves(single.params)):
            np.testing.assert_allclose(np.asarray(gleaf[i]),
                                       np.asarray(sleaf[0]),
                                       rtol=1e-5, atol=1e-6)


def test_sweep_matches_looped_trainer():
    """One sweep lane == the looped FLTrainer on the same config and key
    (noiseless so the per-leaf vs flattened noise layouts cannot differ)."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    for policy, n_atk in [(Policy.BEV, 1), (Policy.CI, 0), (Policy.EF, 2)]:
        floa = _tiny_floa(dim, policy, n_atk, noise=0.0)
        tr = FLTrainer(loss_fn=loss, floa=floa, alpha=0.05)
        rounds = batches["x"].shape[0]
        _, logs = tr.run(dict(params), _Replay(batches), rounds,
                         jax.random.PRNGKey(9), eval_every=1)
        res = SweepEngine(loss, SweepSpec.build(
            [ScenarioCase("x", floa, 0.05, seed=9)])).run(params, batches)
        np.testing.assert_allclose(
            np.asarray([l.loss for l in logs]), res.loss[0],
            rtol=1e-6, atol=1e-7)


def test_sweep_honors_power_accounting_dim():
    """power.dim is the power-accounting D of eq. (4) and may differ from the
    model's true parameter count; the sweep lane must use the config value
    (as FLTrainer does), not the flattened gradient size."""
    loss, params, dim, batches = _tiny_problem(rounds=4)
    floa = _tiny_floa(dim * 7, Policy.BEV, 1, noise=0.0)  # deliberate mismatch
    tr = FLTrainer(loss_fn=loss, floa=floa, alpha=0.05)
    _, logs = tr.run(dict(params), _Replay(batches), 4, jax.random.PRNGKey(9),
                     eval_every=1)
    res = SweepEngine(loss, SweepSpec.build(
        [ScenarioCase("x", floa, 0.05, seed=9)])).run(params, batches)
    np.testing.assert_allclose(np.asarray([l.loss for l in logs]),
                               res.loss[0], rtol=1e-6, atol=1e-7)


def _grid_cases(dim):
    """Policy x attack grid covering every branchless code path (noise,
    jamming, EF early-return, truncated-CI) for the engine-equivalence tests."""
    return [
        ScenarioCase("ci0", _tiny_floa(dim, Policy.CI, 0), 0.05, seed=1),
        ScenarioCase("bev2", _tiny_floa(dim, Policy.BEV, 2), 0.05, seed=2),
        ScenarioCase("ef1", _tiny_floa(dim, Policy.EF, 1), 0.05, seed=3),
        ScenarioCase("tci1", _tiny_floa(dim, Policy.TRUNCATED_CI, 1), 0.04,
                     seed=4),
        ScenarioCase("jam2", _tiny_floa(dim, Policy.BEV, 2,
                                        attack=AttackType.GAUSSIAN), 0.05,
                     seed=5),
        ScenarioCase("sf1", _tiny_floa(
            dim, Policy.CI, 1,
            attack=AttackType.SIGN_FLIP_PROTOCOL_POWER), 0.05, seed=6),
    ]


def test_flat_state_strict_matches_tree_state_bitwise():
    """Under strict_numerics (on BOTH engines) the flat-state scan replays
    the tree-state engine bit-for-bit: same grads (the pytree boundary moves
    inside the loss closure, which is exact), same stats (both reduce
    leaf-segmented off the materialized slab), same combine/update ops.
    Without the flag each path lets XLA fuse its stats reduction into a
    different producer, so they only agree to fp rounding (next test)."""
    loss, params, dim, batches = _tiny_problem(rounds=7)
    spec = SweepSpec.build(_grid_cases(dim))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    tree = SweepEngine(
        loss, spec, eval_fn=eval_fn, plan=ExecutionPlan(
            flat_state=False, strict_numerics=True)).run(params, batches)
    flat = SweepEngine(
        loss, spec, eval_fn=eval_fn,
        plan=ExecutionPlan(strict_numerics=True)).run(params, batches)
    np.testing.assert_array_equal(tree.loss, flat.loss)
    np.testing.assert_array_equal(tree.grad_norm, flat.grad_norm)
    np.testing.assert_array_equal(
        np.asarray(tree.metrics["accuracy"]),
        np.asarray(flat.metrics["accuracy"]))
    for k in tree.params:
        np.testing.assert_array_equal(np.asarray(tree.params[k]),
                                      np.asarray(flat.params[k]))


def test_flat_state_default_matches_tree_state():
    """Default (fast) flat mode lets XLA fuse the stats reduction into the
    gradient producer, so it only agrees with the tree path to fp rounding."""
    loss, params, dim, batches = _tiny_problem(rounds=7)
    spec = SweepSpec.build(_grid_cases(dim))
    tree = SweepEngine(
        loss, spec, plan=ExecutionPlan(flat_state=False)).run(params, batches)
    flat = SweepEngine(loss, spec).run(params, batches)
    np.testing.assert_allclose(tree.loss, flat.loss, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(tree.grad_norm, flat.grad_norm,
                               rtol=1e-5, atol=1e-6)
    for k in tree.params:
        np.testing.assert_allclose(np.asarray(tree.params[k]),
                                   np.asarray(flat.params[k]),
                                   rtol=1e-5, atol=1e-6)


def test_make_row_unflatten_roundtrip():
    from repro.core.aggregation import flatten_worker_grads
    from repro.fl.sweep import make_row_unflatten

    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "b": jnp.arange(4.0) + 10.0,
              "c": jnp.float32(99.0).reshape(())}
    unflatten_row, sizes = make_row_unflatten(params)
    assert sum(sizes) == 11
    flat, _ = flatten_worker_grads(
        jax.tree_util.tree_map(lambda x: x[None], params), batch_dims=1)
    back = unflatten_row(flat[0])
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]))


def test_flat_scalar_stats_matches_tree_stats():
    """Flat stats (segmented or whole-row) reproduce the pytree stats to fp
    rounding.  (The engine-level bitwise guarantee — strict flat == tree —
    is pinned end-to-end by test_flat_state_strict_matches_tree_state_bitwise;
    eagerly, XLA may vectorize a slice-reduce and a leaf-reduce differently,
    so this unit test only asks for tight closeness.)"""
    import repro.core.standardize as STD

    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(U, 7, 3)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(U, 5)).astype(np.float32))}
    gbar_t, eps2_t = STD.per_worker_scalar_stats(grads)
    from repro.core.aggregation import flatten_worker_grads
    flat, _ = flatten_worker_grads(grads, batch_dims=1)
    for sizes in ((21, 5), None):
        gbar_f, eps2_f = STD.flat_scalar_stats(flat, sizes=sizes)
        np.testing.assert_allclose(np.asarray(gbar_t), np.asarray(gbar_f),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(eps2_t), np.asarray(eps2_f),
                                   rtol=1e-6, atol=1e-7)


def test_flat_partial_stats_recombine_and_ignore_zero_padding():
    """The model-sharded stats contract: column-block partial sums, summed
    across shards and finished by `stats_from_partials` with the REAL D,
    reproduce the unsharded `flat_scalar_stats`; zero ghost-pad columns
    contribute exactly nothing."""
    import repro.core.standardize as STD

    rng = np.random.default_rng(1)
    u, d, pad, shards = 5, 37, 11, 4
    flat = jnp.asarray(rng.normal(size=(u, d)).astype(np.float32))
    padded = jnp.pad(flat, ((0, 0), (0, pad)))
    d_loc = (d + pad) // shards
    s1 = jnp.zeros((u,), jnp.float32)
    s2 = jnp.zeros((u,), jnp.float32)
    for m in range(shards):
        p1, p2 = STD.flat_partial_stats(
            padded[:, m * d_loc:(m + 1) * d_loc])
        s1, s2 = s1 + p1, s2 + p2
    gbar, eps2 = STD.stats_from_partials(s1, s2, d)
    gbar_ref, eps2_ref = STD.flat_scalar_stats(flat)
    np.testing.assert_allclose(np.asarray(gbar), np.asarray(gbar_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(eps2), np.asarray(eps2_ref),
                               rtol=1e-6, atol=1e-7)
    # Whole-row partials (single shard, no padding) finish to the same
    # values exactly — the epilogue is the identical mean/floor math.
    w1, w2 = STD.flat_partial_stats(flat)
    g2, e2 = STD.stats_from_partials(w1, w2, d)
    np.testing.assert_array_equal(np.asarray(g2), np.asarray(gbar_ref))
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(eps2_ref))


def test_scenario_pad_lanes():
    cfgs = [_floa(Policy.CI, AttackType.NONE, 0),
            _floa(Policy.BEV, AttackType.STRONGEST, 2)]
    stacked = SC.stack(tuple(SC.from_floa(c, alpha=0.1) for c in cfgs))
    padded = SC.pad_lanes(stacked, 5)
    for leaf_p, leaf_s in zip(jax.tree_util.tree_leaves(padded),
                              jax.tree_util.tree_leaves(stacked)):
        assert leaf_p.shape[0] == 5
        np.testing.assert_array_equal(np.asarray(leaf_p[:2]),
                                      np.asarray(leaf_s))
        for g in range(2, 5):  # ghost lanes replicate the last real lane
            np.testing.assert_array_equal(np.asarray(leaf_p[g]),
                                          np.asarray(leaf_s[-1]))
    assert SC.pad_lanes(stacked, 2) is stacked


def test_run_scan_flat_matches_sweep_lane():
    """FLTrainer.run_scan(flat=True) delegates to a single-lane flat-state
    sweep; it must reproduce that engine's lane bit-for-bit."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    floa = _tiny_floa(dim, Policy.BEV, 1)
    tr = FLTrainer(loss_fn=loss, floa=floa, alpha=0.05)
    key = jax.random.PRNGKey(7)
    p_flat, logs_flat = tr.run_scan(dict(params), batches, key, eval_every=1,
                                    flat=True)
    eng = SweepEngine(loss, SweepSpec.build(
        [ScenarioCase("scan", floa, 0.05)]), eval_every=0)
    res = eng.run(params, batches, keys=key[None])
    np.testing.assert_array_equal(
        np.asarray([l.loss for l in logs_flat]), res.loss[0])
    np.testing.assert_array_equal(
        np.asarray([l.grad_norm for l in logs_flat]), res.grad_norm[0])
    for k in params:
        np.testing.assert_array_equal(
            np.asarray(p_flat[k]), np.asarray(res.params[k][0]))


def test_run_scan_flat_matches_loop_noiseless():
    """On noiseless channels (where the per-leaf vs flattened noise layouts
    cannot differ) the flat run_scan replays the looped trainer to fp
    rounding."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    floa = _tiny_floa(dim, Policy.BEV, 1, noise=0.0)
    tr = FLTrainer(loss_fn=loss, floa=floa, alpha=0.05)
    rounds = batches["x"].shape[0]
    p_loop, logs_loop = tr.run(dict(params), _Replay(batches), rounds,
                               jax.random.PRNGKey(9), eval_every=1)
    p_flat, logs_flat = tr.run_scan(dict(params), batches,
                                    jax.random.PRNGKey(9), eval_every=1,
                                    flat=True)
    np.testing.assert_allclose(
        np.asarray([l.loss for l in logs_loop]),
        np.asarray([l.loss for l in logs_flat]), rtol=1e-6, atol=1e-7)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_loop[k]),
                                   np.asarray(p_flat[k]),
                                   rtol=1e-5, atol=1e-6)


def test_sweep_metrics_and_logs_schedule():
    loss, params, dim, batches = _tiny_problem(rounds=6)
    spec = SweepSpec.build(
        [ScenarioCase("a", _tiny_floa(dim), 0.05, seed=0),
         ScenarioCase("b", _tiny_floa(dim, n_atk=0), 0.05, seed=1)])
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    res = SweepEngine(loss, spec, eval_fn=eval_fn).run(params, batches)
    assert res.loss.shape == (2, 6)
    assert res.metrics["accuracy"].shape == (2, 6)
    logs = res.logs("b", eval_every=2)
    assert [l.step for l in logs] == [0, 2, 4, 5]
    assert logs[-1].accuracy == 0.5


def test_stack_rounds_replays_sampler_stream():
    shards = toy_shards(0, U)
    a = FederatedSampler(shards, batch_per_worker=4, seed=11)
    b = FederatedSampler(shards, batch_per_worker=4, seed=11)
    stacked = a.stack_rounds(3)
    for t in range(3):
        nxt = b.next_round()
        for k in nxt:
            np.testing.assert_array_equal(stacked[k][t], nxt[k])
