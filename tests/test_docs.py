"""Docs health: the tools/check_docs.py checker itself, and the repo's
actual README + docs/ tree passing it (links + snippet syntax; snippet
EXECUTION happens in the CI docs job with --run-snippets)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


def test_github_slug_rules():
    assert check_docs.github_slug("## Chunked rounds and async batch "
                                  "staging") == \
        "chunked-rounds-and-async-batch-staging"
    assert check_docs.github_slug("# The `SweepEngine` class!") == \
        "the-sweepengine-class"
    assert check_docs.github_slug("### A, B & C — d/e") == "a-b--c--de"
    # GitHub PRESERVES underscores (it only drops emphasis/code markers):
    assert check_docs.github_slug("## Running `sweep_bench.py`") == \
        "running-sweep_benchpy"
    assert check_docs.github_slug("## Reading `BENCH_sweep.json`") == \
        "reading-bench_sweepjson"


def test_iter_links_skips_external_and_fences():
    text = textwrap.dedent("""
        [ok](docs/sweeps.md) [ext](https://x.test/a.md) [anc](a.md#sec)
        ```python
        x = "[not a link](fake.md)"
        ```
        [self](#here)
    """)
    links = list(check_docs.iter_links(text))
    assert ("docs/sweeps.md", "") in links
    assert ("a.md", "sec") in links
    assert ("", "here") in links
    assert all("x.test" not in p for p, _ in links)
    assert not any("fake.md" in p for p, _ in links)


def test_iter_code_blocks_and_smoke_marker():
    text = textwrap.dedent("""
        <!-- docs-smoke -->
        ```python
        print("run me")
        ```
        ```bash
        python benchmarks/sweep_bench.py
        ```
    """)
    blocks = list(check_docs.iter_code_blocks(text))
    assert [(l, m) for l, _, m in blocks] == [("python", True),
                                              ("bash", False)]


def test_broken_link_and_anchor_detected(tmp_path):
    good = tmp_path / "good.md"
    good.write_text("# Real Heading\nbody\n")
    bad = tmp_path / "bad.md"
    bad.write_text("[a](missing.md) [b](good.md#real-heading) "
                   "[c](good.md#nope)\n")
    old = check_docs.REPO_ROOT
    check_docs.REPO_ROOT = str(tmp_path)
    try:
        errors = check_docs.check_links(str(bad))
    finally:
        check_docs.REPO_ROOT = old
    assert len(errors) == 2
    assert any("missing.md" in e for e in errors)
    assert any("#nope" in e for e in errors)


def test_snippet_syntax_error_detected(tmp_path):
    md = tmp_path / "x.md"
    md.write_text("```python\ndef broken(:\n```\n")
    errors = check_docs.check_snippets(str(md), run=False)
    assert len(errors) == 1 and "syntax error" in errors[0]


def test_bash_block_missing_file_detected(tmp_path):
    md = tmp_path / "x.md"
    md.write_text("```bash\npython no/such/script.py --flag\n```\n")
    errors = check_docs.check_snippets(str(md), run=False)
    assert len(errors) == 1 and "no/such/script.py" in errors[0]


def test_repo_docs_pass_link_and_syntax_check():
    """The committed README + docs/ tree must be healthy (the CI docs job
    additionally executes the <!-- docs-smoke --> marked snippets)."""
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_repo_docs_have_smoke_snippets():
    """At least one executable snippet each in sweeps.md and benchmarks.md —
    the docs job must have something real to run."""
    for name in ("sweeps.md", "benchmarks.md"):
        with open(os.path.join(REPO, "docs", name), encoding="utf-8") as f:
            blocks = list(check_docs.iter_code_blocks(f.read()))
        assert any(lang == "python" and marked
                   for lang, _, marked in blocks), name
