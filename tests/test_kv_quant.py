"""int8 KV-cache quantization (§Perf memory-term optimization): decode with a
quantized cache must track the exact-cache decode closely."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as T


@pytest.mark.slow  # full decode loop, ~1 min on CPU
def test_int8_kv_decode_close_to_exact():
    cfg = get_smoke("qwen3-4b")
    cfg_q = dataclasses.replace(cfg, kv_cache_dtype="int8")
    s = 24
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, s), 0, cfg.vocab_size)
    params, _ = T.init_lm(jax.random.PRNGKey(1), cfg)

    def run(c):
        caches = T.init_caches(c, 2, s)
        outs = []
        for i in range(s):
            lg, caches = T.decode_step(params, caches, toks[:, i:i + 1],
                                       jnp.int32(i), c)
            outs.append(lg[:, 0])
        return jnp.stack(outs, axis=1)

    exact = run(cfg)
    quant = run(cfg_q)
    # logits track closely; argmax (greedy decode) nearly always agrees
    rel = float(jnp.max(jnp.abs(quant - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    assert rel < 0.05, rel
    agree = float(jnp.mean(
        (jnp.argmax(quant, -1) == jnp.argmax(exact, -1)).astype(jnp.float32)))
    assert agree > 0.9, agree


def test_int8_cache_is_half_the_bytes():
    cfg = get_smoke("qwen3-4b")
    cfg_q = dataclasses.replace(cfg, kv_cache_dtype="int8")
    c0 = T.init_caches(cfg, 2, 64)
    c1 = T.init_caches(cfg_q, 2, 64)

    def nbytes(c):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(c))

    # f32 smoke cache -> int8 + f16 scales: > 3.5x smaller (bf16 prod: ~2x)
    assert nbytes(c1) < nbytes(c0) / 3
