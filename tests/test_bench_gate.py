"""The sweep-bench perf-regression gate (`sweep_bench.check_regressions`)
is pure record-vs-record logic, so its contract is pinned here without
running the bench: rows regress only below baseline * (1 - tolerance),
shape-mismatched rows are skipped (reported), and missing rows never fail.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

pytest.importorskip("benchmarks.sweep_bench")
from benchmarks.sweep_bench import check_regressions


def _rec(engines=None, defenses=None, scenarios=16, rounds=25,
         chunk_rounds=5):
    rec = {"scenarios": scenarios, "rounds": rounds,
           "chunk_rounds": chunk_rounds}
    if engines:
        rec["engines"] = {k: {"warm_rounds_per_sec": v}
                          for k, v in engines.items()}
    if defenses:
        rec["defenses"] = {k: {"warm_rounds_per_sec": v, "lanes": 6,
                               "rounds": 10} for k, v in defenses.items()}
    return rec


def test_gate_passes_within_tolerance():
    base = _rec(engines={"flat": 100.0}, defenses={"mixed": 40.0})
    fresh = _rec(engines={"flat": 51.0}, defenses={"mixed": 20.1})
    fails, notes = check_regressions(fresh, base, tolerance=0.5)
    assert fails == [] and notes == []


def test_gate_fails_below_floor():
    base = _rec(engines={"flat": 100.0}, defenses={"mixed": 40.0})
    fresh = _rec(engines={"flat": 49.0}, defenses={"mixed": 41.0})
    fails, _ = check_regressions(fresh, base, tolerance=0.5)
    assert len(fails) == 1 and "engines/flat" in fails[0]


def test_gate_skips_shape_mismatches():
    base = _rec(engines={"flat": 100.0}, defenses={"mixed": 40.0})
    # different headline grid shape: engine rows must be skipped, not failed
    fresh = _rec(engines={"flat": 1.0}, defenses={"mixed": 40.0}, scenarios=4)
    fails, notes = check_regressions(fresh, base, tolerance=0.5)
    assert fails == [] and any("engine rows skipped" in n for n in notes)
    # per-defense lane/round mismatch: that row is skipped
    fresh2 = _rec(engines={"flat": 100.0}, defenses={"mixed": 1.0})
    fresh2["defenses"]["mixed"]["lanes"] = 3
    fails2, notes2 = check_regressions(fresh2, base, tolerance=0.5)
    assert fails2 == [] and any("defenses/mixed" in n for n in notes2)


def test_gate_skips_chunk_rows_on_chunk_rounds_mismatch():
    """A different --chunk-rounds is a different program shape for the
    flat+chunk rows only: those skip (reported), the rest still gate."""
    base = _rec(engines={"flat": 100.0, "flat+chunk": 100.0,
                         "flat+chunk+async": 100.0})
    fresh = _rec(engines={"flat": 80.0, "flat+chunk": 1.0,
                          "flat+chunk+async": 1.0}, chunk_rounds=1)
    fails, notes = check_regressions(fresh, base, tolerance=0.5)
    assert fails == []
    assert sum("chunk_rounds differs" in n for n in notes) == 2
    # and a non-chunk row still fails on the same records
    fresh["engines"]["flat"]["warm_rounds_per_sec"] = 1.0
    fails2, _ = check_regressions(fresh, base, tolerance=0.5)
    assert len(fails2) == 1 and "engines/flat:" in fails2[0]


def _resume_row(chunked=100.0, ckpt=90.0, lanes=8, rounds=10,
                chunk_rounds=5, dim=50890):
    return {"lanes": lanes, "rounds": rounds, "chunk_rounds": chunk_rounds,
            "dim": dim,
            "chunked": {"warm_rounds_per_sec": chunked},
            "chunked_ckpt": {"warm_rounds_per_sec": ckpt},
            "cache": {"cold_s": 10.0, "warm_s": 1.0,
                      "warm_restart_speedup": 10.0}}


def test_gate_resume_rows():
    """The resume section gates its chunked/chunked_ckpt warm rows
    shape-aware (lanes/rounds/chunk_rounds/dim) and never gates the
    subprocess cache timings."""
    base = _rec(engines={"flat": 100.0})
    base["resume"] = _resume_row()
    # within tolerance, cache wildly slower: passes (cache is not gated)
    fresh = _rec(engines={"flat": 100.0})
    fresh["resume"] = _resume_row(chunked=51.0, ckpt=46.0)
    fresh["resume"]["cache"] = {"cold_s": 10.0, "warm_s": 10.0,
                                "warm_restart_speedup": 1.0}
    fails, notes = check_regressions(fresh, base, tolerance=0.5)
    assert fails == [] and notes == []
    # a collapsed checkpointed row fails
    fresh["resume"]["chunked_ckpt"]["warm_rounds_per_sec"] = 1.0
    fails2, _ = check_regressions(fresh, base, tolerance=0.5)
    assert len(fails2) == 1 and "resume/chunked_ckpt" in fails2[0]
    # a different resume grid shape skips instead
    fresh["resume"]["lanes"] = 4
    fails3, notes3 = check_regressions(fresh, base, tolerance=0.5)
    assert fails3 == [] and any("resume" in n for n in notes3)
    # resume missing from the fresh run: skipped, reported
    del fresh["resume"]
    fails4, notes4 = check_regressions(fresh, base, tolerance=0.5)
    assert fails4 == [] and any("resume: not in fresh run" in n
                                for n in notes4)


def _lm_row(unsharded=100.0, sharded=80.0, d=50000, model_shards=8):
    row = {"d": d, "u": 8, "lanes": 2, "rounds": 3,
           "model_shards": model_shards,
           "unsharded": {"warm_rounds_per_sec": unsharded}}
    if sharded is not None:
        row["model_sharded"] = {"warm_rounds_per_sec": sharded}
    return row


def test_gate_lm_rows():
    """The --lm D-scaling section gates both its unsharded and
    model-sharded warm rows, shape-aware in (d, u, lanes, rounds,
    model_shards)."""
    base = _rec(engines={"flat": 100.0})
    base["lm"] = {"D50000": _lm_row()}
    fresh = _rec(engines={"flat": 100.0})
    fresh["lm"] = {"D50000": _lm_row(unsharded=51.0, sharded=41.0)}
    fails, notes = check_regressions(fresh, base, tolerance=0.5)
    assert fails == [] and notes == []
    # a collapsed model-sharded row fails
    fresh["lm"]["D50000"]["model_sharded"]["warm_rounds_per_sec"] = 1.0
    fails2, _ = check_regressions(fresh, base, tolerance=0.5)
    assert len(fails2) == 1 and "lm/D50000/model_sharded" in fails2[0]
    # a different device count is a different program shape: skipped
    fresh["lm"]["D50000"]["model_shards"] = 1
    fails3, notes3 = check_regressions(fresh, base, tolerance=0.5)
    assert fails3 == [] and any("lm/D50000" in n for n in notes3)
    # single-device fresh run without the sharded sub-row: skipped, noted
    fresh["lm"]["D50000"] = _lm_row(sharded=None)
    fails4, notes4 = check_regressions(fresh, base, tolerance=0.5)
    assert fails4 == [] and any("lm/D50000/model_sharded" in n
                                for n in notes4)
    # a D missing from the fresh series: skipped, noted
    del fresh["lm"]["D50000"]
    fails5, notes5 = check_regressions(fresh, base, tolerance=0.5)
    assert fails5 == [] and any("lm/D50000: not in fresh" in n
                                for n in notes5)


def test_gate_skips_missing_rows():
    base = _rec(engines={"flat": 100.0, "looped": 10.0},
                defenses={"mixed": 40.0, "krum": 70.0})
    fresh = _rec(engines={"flat": 100.0}, defenses={"mixed": 40.0})
    fails, notes = check_regressions(fresh, base, tolerance=0.5)
    assert fails == []
    assert any("engines/looped" in n for n in notes)
    assert any("defenses/krum" in n for n in notes)
