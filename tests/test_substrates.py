"""Substrate tests: data pipeline, optimizers, schedules, checkpointing,
defenses, MoE dispatch equivalence, SSM/RG-LRU numerics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e '.[test]'; CI's tier-1 job has it)")
from hypothesis import given, settings, strategies as st

from repro import checkpoint as CK
from repro.core import defenses as DEF
from repro.data import FederatedSampler, make_dataset, sample_tokens, worker_split
from repro.optim import adamw, apply_updates, constant, sgd, warmup_cosine


def test_synthetic_digits_learnable_and_deterministic():
    x1, y1 = make_dataset(64, seed=5)
    x2, y2 = make_dataset(64, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 784) and x1.min() >= 0 and x1.max() <= 1
    assert set(np.unique(y1)) <= set(range(10))


def test_worker_split_partitions():
    x, y = make_dataset(100, seed=0)
    shards = worker_split(x, y, 7)
    assert sum(len(s[0]) for s in shards.values()) == 100


def test_federated_sampler_layout():
    x, y = make_dataset(100, seed=0)
    s = FederatedSampler(worker_split(x, y, 5), batch_per_worker=4, seed=0)
    b = s.next_round()
    assert b["x"].shape == (20, 784)
    # worker-major layout: reshape recovers per-worker blocks
    assert b["x"].reshape(5, 4, 784).shape == (5, 4, 784)


def test_token_stream_structured():
    t = sample_tokens(8, 256, vocab=101, seed=0)
    assert t.shape == (8, 256) and t.max() < 101
    # markov structure: bigram entropy < unigram entropy upper bound
    t2 = sample_tokens(8, 256, vocab=101, seed=0)
    np.testing.assert_array_equal(t, t2)


def test_sgd_momentum_and_adamw_descend():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for opt in (sgd(0.0), sgd(0.9), adamw()):
        params = {"w": jnp.zeros((4,))}
        state = opt.init(params)
        for _ in range(50):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params, 0.1)
            params = apply_updates(params, upd)
        assert float(loss(params)) < 0.5


def test_schedules():
    fn = warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(fn(99)) < 0.3
    assert float(constant(0.5)(7)) == 0.5


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    opt_state = {"mu": {"a": jnp.zeros((2, 3)),
                        "nested": {"b": jnp.zeros((4,), jnp.float32)}}}
    path = str(tmp_path / "ck")
    CK.save(path, 42, params, opt_state, extra={"note": "x"})
    assert CK.latest_step(path) == 42
    p2, o2, meta = CK.restore(path, 42, params, opt_state)
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(params["a"]))
    assert p2["nested"]["b"].dtype == jnp.bfloat16
    assert meta["extra"]["note"] == "x"


# --- defenses ----------------------------------------------------------------


def _stack(gs):
    return {"w": jnp.stack(gs)}


def test_median_krum_screen_outliers():
    rng = np.random.default_rng(0)
    honest = [rng.normal(0, 0.1, 16) + 1.0 for _ in range(7)]
    evil = [np.full(16, -50.0) for _ in range(3)]
    grads_u = _stack([jnp.asarray(g, jnp.float32) for g in honest + evil])
    med = DEF.coordinate_median(grads_u)["w"]
    assert np.all(np.asarray(med) > 0.5)
    krum = DEF.krum(grads_u, num_byzantine=3)["w"]
    assert np.all(np.asarray(krum) > 0.5)
    tm = DEF.trimmed_mean(grads_u, trim=3)["w"]
    assert np.all(np.asarray(tm) > 0.5)
    gm = DEF.geometric_median(grads_u)["w"]
    assert np.all(np.asarray(gm) > 0.0)
    # plain mean IS poisoned (the paper's motivation)
    mean = DEF.digital_aggregate(grads_u, "mean")["w"]
    assert np.all(np.asarray(mean) < 0.0)


@given(st.integers(5, 12), st.integers(0, 2))
@settings(max_examples=20, deadline=None)
def test_property_trimmed_mean_bounded(u, trim):
    rng = np.random.default_rng(u)
    g = rng.normal(size=(u, 8)).astype(np.float32)
    if 2 * trim >= u:
        return
    tm = np.asarray(DEF.trimmed_mean({"w": jnp.asarray(g)}, trim=trim)["w"])
    assert np.all(tm <= g.max(0) + 1e-6) and np.all(tm >= g.min(0) - 1e-6)


# --- MoE dispatch equivalence -------------------------------------------------


def test_moe_impls_agree():
    from repro.models.common import ModelConfig, MoEConfig
    from repro.models import moe as MOE
    import dataclasses

    cfg = ModelConfig(name="m", arch_type="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype=jnp.float32,
                      moe=MoEConfig(num_experts=4, top_k=2, d_expert=16,
                                    capacity_factor=4.0, impl="scan_dense"))
    from repro.models.common import ParamFactory
    fac = ParamFactory(jax.random.PRNGKey(0), dtype=jnp.float32)
    MOE.init_moe(fac, "ffn", cfg)
    p, _ = fac.collect()
    x = jax.random.normal(jax.random.PRNGKey(1), (48, 32))
    y1, a1 = MOE.moe_scan_dense(p["ffn"], x, cfg)
    cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, impl="capacity_gather"))
    y2, a2 = MOE.moe_capacity_gather(p["ffn"], x, cfg2)
    # with generous capacity nothing is dropped -> identical outputs
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)
