"""Dirichlet(alpha) non-IID partition contracts (data/pipeline.py).

The adaptive-adversary experiments need label-skew shards; these tests pin
the degenerate and monotonicity contracts so the sampler can be trusted as a
scenario axis: alpha = inf is a deterministic stratified IID split (balanced
per class to +-1), smaller alpha is strictly more skewed, every partition is
a true partition of the dataset, and the min_per_worker floor always holds.
"""
import numpy as np
import pytest

from repro.data import dirichlet_worker_split
from repro.data.pipeline import FederatedSampler
from repro.data.synthetic_digits import make_dataset

U = 4


@pytest.fixture(scope="module")
def dataset():
    return make_dataset(400, seed=1)


def _class_tv_skew(shards, y):
    """Sum over classes of total-variation distance between the realized
    worker proportions and uniform 1/U — 0 iff perfectly class-balanced."""
    u = len(shards)
    tv = 0.0
    for c in np.unique(y):
        per = np.array([np.sum(shards[i][1] == c) for i in range(u)], float)
        per /= max(per.sum(), 1.0)
        tv += 0.5 * np.abs(per - 1.0 / u).sum()
    return tv


def test_alpha_inf_is_stratified_and_balanced(dataset):
    x, y = dataset
    shards = dirichlet_worker_split(x, y, U, np.inf, seed=3)
    assert len(shards) == U
    for c in np.unique(y):
        per = [int(np.sum(shards[i][1] == c)) for i in range(U)]
        assert max(per) - min(per) <= 1, f"class {c} unbalanced: {per}"


def test_partition_is_exact(dataset):
    """Union of shards == dataset, no sample duplicated or dropped."""
    x, y = dataset
    for alpha in (np.inf, 1.0, 0.1):
        shards = dirichlet_worker_split(x, y, U, alpha, seed=5)
        ys = np.concatenate([shards[i][1] for i in range(U)])
        assert len(ys) == len(y)
        np.testing.assert_array_equal(np.sort(ys), np.sort(y))
        xsums = np.concatenate([shards[i][0].sum(axis=1) for i in range(U)])
        np.testing.assert_allclose(np.sort(xsums), np.sort(x.sum(axis=1)),
                                   rtol=1e-6)


def test_deterministic_in_seed(dataset):
    x, y = dataset
    a = dirichlet_worker_split(x, y, U, 0.5, seed=11)
    b = dirichlet_worker_split(x, y, U, 0.5, seed=11)
    for i in range(U):
        np.testing.assert_array_equal(a[i][1], b[i][1])
        np.testing.assert_array_equal(a[i][0], b[i][0])
    c = dirichlet_worker_split(x, y, U, 0.5, seed=12)
    assert any(not np.array_equal(a[i][1], c[i][1]) for i in range(U))


def test_skew_increases_as_alpha_shrinks(dataset):
    """Averaged over seeds, alpha=0.1 shards are more label-skewed than
    alpha=100 shards, which in turn sit near the alpha=inf stratified split."""
    x, y = dataset
    skew = lambda alpha: np.mean([
        _class_tv_skew(dirichlet_worker_split(x, y, U, alpha, seed=s), y)
        for s in range(5)])
    s_inf, s_hi, s_lo = skew(np.inf), skew(100.0), skew(0.1)
    assert s_lo > s_hi > s_inf


def test_min_per_worker_floor(dataset):
    x, y = dataset
    shards = dirichlet_worker_split(x, y, U, 0.01, seed=7, min_per_worker=5)
    assert all(len(shards[i][1]) >= 5 for i in range(U))
    np.testing.assert_array_equal(
        np.sort(np.concatenate([shards[i][1] for i in range(U)])), np.sort(y))


def test_validation_errors(dataset):
    x, y = dataset
    with pytest.raises(ValueError):
        dirichlet_worker_split(x, y, U, 0.0)
    with pytest.raises(ValueError):
        dirichlet_worker_split(x, y, U, float("nan"))
    with pytest.raises(ValueError):
        dirichlet_worker_split(x, y, 0, 1.0)
    with pytest.raises(ValueError):
        dirichlet_worker_split(x[:3], y[:3], U, 1.0)


def test_sampler_classmethod_batches(dataset):
    x, y = dataset
    fs = FederatedSampler.dirichlet(x, y, U, 0.5, batch_per_worker=8, seed=2)
    assert fs.num_workers == U
    b = fs.next_round()
    assert b["x"].shape == (U * 8, x.shape[1])
    assert b["y"].shape == (U * 8,)
    # Worker-ordered concatenation: block i draws only from shard i's labels.
    shards = dirichlet_worker_split(x, y, U, 0.5, seed=2)
    for i in range(U):
        block = b["y"][i * 8:(i + 1) * 8]
        assert set(np.unique(block)) <= set(np.unique(shards[i][1]))
