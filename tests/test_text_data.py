"""Markov token-stream corpus (data/text.py): the LM sweep lane's input.

Pins the generator's contracts — determinism in the seed, token-range and
shape invariants, per-round independence of `stack_token_rounds` — and the
composition with the federated pipeline: tokens dealt through
`FederatedSampler` (including the PR-8 Dirichlet label-skew split over
first-token classes) round-trip into the [R, U*B, S] layout
`per_worker_grads` consumes.
"""
import numpy as np
import pytest

from repro.data import FederatedSampler, TokenBatcher
from repro.data.text import (
    make_markov_tables,
    sample_tokens,
    stack_token_rounds,
)


def test_markov_tables_deterministic_and_in_range():
    a = make_markov_tables(vocab=97, seed=3)
    b = make_markov_tables(vocab=97, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (97, 16)
    assert a.min() >= 0 and a.max() < 97
    c = make_markov_tables(vocab=97, seed=4)
    assert not np.array_equal(a, c)
    assert make_markov_tables(vocab=97, seed=3, branch=5).shape == (97, 5)


def test_markov_tables_zipf_prior_skews_successors():
    """The Zipf(1.1) successor prior must actually skew the tables: low
    token ids (head of the prior) appear as successors far more often than
    a uniform draw would allow."""
    succ = make_markov_tables(vocab=512, seed=0)
    head_share = np.mean(succ < 16)
    assert head_share > 0.25          # uniform would give 16/512 = 0.03


@pytest.mark.parametrize("n_seqs,seq_len,vocab", [(4, 32, 64), (1, 1, 2),
                                                  (8, 129, 1000)])
def test_sample_tokens_shape_dtype_range(n_seqs, seq_len, vocab):
    toks = sample_tokens(n_seqs, seq_len, vocab, seed=1)
    assert toks.shape == (n_seqs, seq_len)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < vocab


def test_sample_tokens_deterministic_in_seed():
    a = sample_tokens(6, 40, 128, seed=7)
    np.testing.assert_array_equal(a, sample_tokens(6, 40, 128, seed=7))
    assert not np.array_equal(a, sample_tokens(6, 40, 128, seed=8))


def test_sample_tokens_follow_the_tables():
    """Transitions overwhelmingly land in the sampled token's successor row
    (only the 10% restarts escape it) — the planted structure an LM can
    actually learn."""
    vocab, seed = 64, 5
    succ = make_markov_tables(vocab, seed)
    toks = sample_tokens(16, 200, vocab, seed=seed)
    cur, nxt = toks[:, :-1].ravel(), toks[:, 1:].ravel()
    in_table = np.array([n in succ[c] for c, n in zip(cur, nxt)])
    assert in_table.mean() > 0.8


def test_stack_token_rounds_layout_and_per_round_independence():
    r, n, s, v = 5, 6, 20, 128
    stack = stack_token_rounds(r, n, s, v, seed=3)
    assert stack.shape == (r, n, s) and stack.dtype == np.int32
    # Round t is exactly sample_tokens at seed + t ...
    for t in range(r):
        np.testing.assert_array_equal(stack[t],
                                      sample_tokens(n, s, v, seed=3 + t))
    # ... so consecutive rounds are genuinely different draws.
    assert not np.array_equal(stack[0], stack[1])


def test_token_batcher_over_markov_stream():
    """TokenBatcher + sample_tokens: the train-step input layout ([B, S+1]
    under the "tokens" key), fresh batch per step."""
    bt = TokenBatcher(lambda b, s: sample_tokens(b, s, 64, seed=0),
                      global_batch=4, seq_len=16)
    first = next(bt)
    assert set(first) == {"tokens"} and first["tokens"].shape == (4, 17)
    assert bt.step == 1


def test_federated_sampler_over_tokens_round_trip():
    """Tokens dealt as per-worker shards through FederatedSampler come back
    in worker-major order: batch.reshape(U, B, S) recovers each worker's
    own sequences (the per_worker_grads layout), and a same-seed sampler
    replays the identical stream."""
    u, bpw, s, v = 4, 3, 12, 64
    pool = sample_tokens(40, s, v, seed=2)
    labels = pool[:, 0].astype(np.int64)
    shards = {i: (pool[i * 10:(i + 1) * 10], labels[i * 10:(i + 1) * 10])
              for i in range(u)}
    smp = FederatedSampler(shards, batch_per_worker=bpw, seed=9)
    batch = smp.next_round()
    assert batch["x"].shape == (u * bpw, s)
    by_worker = batch["x"].reshape(u, bpw, s)
    for i in range(u):
        pool_i = {tuple(row) for row in shards[i][0]}
        for row in by_worker[i]:
            assert tuple(row) in pool_i
    replay = FederatedSampler(shards, batch_per_worker=bpw, seed=9)
    np.testing.assert_array_equal(replay.next_round()["x"], batch["x"])


def test_dirichlet_split_composes_with_token_stream():
    """PR-8 composition: a Dirichlet label-skew split over first-token
    classes feeds the same stacked [R, U*B, S] layout the sweep engine
    consumes, deterministically."""
    u, bpw, s, v, rounds = 4, 2, 10, 16, 3
    pool = sample_tokens(64, s, v, seed=1)
    labels = pool[:, 0].astype(np.int64)
    smp = FederatedSampler.dirichlet(pool, labels, num_workers=u, alpha=0.5,
                                     batch_per_worker=bpw, seed=11)
    stack = smp.stack_rounds(rounds)
    assert stack["x"].shape == (rounds, u * bpw, s)
    assert stack["x"].min() >= 0 and stack["x"].max() < v
    replay = FederatedSampler.dirichlet(pool, labels, num_workers=u,
                                        alpha=0.5, batch_per_worker=bpw,
                                        seed=11)
    np.testing.assert_array_equal(replay.stack_rounds(rounds)["x"],
                                  stack["x"])
    # alpha -> 0 concentrates: some worker's shard must be label-skewed
    # away from the global first-token distribution.
    skew = FederatedSampler.dirichlet(pool, labels, num_workers=u,
                                      alpha=0.05, batch_per_worker=bpw,
                                      seed=11)
    sizes = sorted(len(x) for x, _ in skew.shards.values())
    assert sizes[0] < sizes[-1]
