"""End-to-end behaviour tests for the FLOA system (paper pipeline glue)."""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_smoke
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    first_n_mask, floa_grad,
)
from repro.launch.hlo_analysis import (
    active_params, collective_bytes, dominant, model_flops, roofline_terms,
)
from repro.models.mlp import init_mlp, mlp_loss


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    kinds = {get_smoke(a).arch_type for a in ARCH_IDS}
    assert kinds == {"dense", "vlm", "ssm", "moe", "hybrid", "audio"}
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}


def test_full_configs_match_assignment():
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (60, 5120, 128, 102400)
    assert c.moe.num_experts == 160 and c.moe.top_k == 6 and c.mla.kv_lora == 512
    c = get_config("starcoder2-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (30, 3072, 24, 2, 12288, 49152)
    c = get_config("mamba2-1.3b")
    assert c.ssm.d_state == 128 and c.vocab_size == 50280
    c = get_config("llama4-maverick-400b-a17b")
    assert c.moe.num_experts == 128 and c.moe.top_k == 1
    c = get_config("seamless-m4t-large-v2")
    assert c.vocab_size == 256206 and "long_500k" in c.skip_shapes


def test_floa_grad_end_to_end_mlp():
    u, d = 10, 50890
    cfg = FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=0.001),
        power=PowerConfig(num_workers=u, dim=d, p_max=1.0, policy=Policy.BEV),
        attack=AttackConfig(attack=AttackType.STRONGEST,
                            byzantine_mask=first_n_mask(u, 2)),
    )
    params = init_mlp(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"x": jax.random.normal(key, (40, 784)),
             "y": jax.random.randint(key, (40,), 0, 10)}
    g, aux = jax.jit(lambda p, b, k: floa_grad(mlp_loss, p, b, k, cfg))(
        params, batch, key)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(g))
    assert np.asarray(aux["coeffs"])[:2].max() < 0  # attackers flipped
    assert np.asarray(aux["coeffs"])[2:].min() > 0


def test_hlo_collective_parser():
    hlo = """
  %ar = bf16[16,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[8,128]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[4,64]{1,0}, f32[4,64]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %done = f32[8,128]{1,0} all-gather-done(%ag.1)
  %cp = u32[2]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 16 * 256 * 2
    assert cb["all-gather"] == 8 * 128 * 4
    assert cb["reduce-scatter"] == 2 * 4 * 64 * 4
    assert cb["collective-permute"] == 2 * 4
    assert cb["total"] == sum(cb[k] for k in
                              ("all-reduce", "all-gather", "reduce-scatter",
                               "all-to-all", "collective-permute"))


def test_roofline_terms_and_dominant():
    t = roofline_terms(197e12, 819e9, 50e9)  # exactly 1 second each
    assert np.isclose(t["compute_s"], 1.0) and np.isclose(t["memory_s"], 1.0)
    t2 = roofline_terms(1e12, 819e9 * 5, 0)
    assert dominant(t2) == "memory_s"


def test_model_flops_and_active_params():
    cfg = get_config("deepseek-v2-236b")
    n = 236_000_000_000
    na = active_params(cfg, n)
    assert na < n * 0.2  # MoE: active params << total
    sh = dict(seq_len=4096, global_batch=256, kind="train")
    assert model_flops(cfg, sh, n, na) == 6 * na * 4096 * 256
    shd = dict(seq_len=32768, global_batch=128, kind="decode")
    assert model_flops(cfg, shd, n, na) == 2 * na * 128
