"""Defense-code lane axis contracts.

  - the matrix-native [U, D] kernels reproduce the pytree
    `digital_aggregate` path (rtol 1e-6) on multi-leaf gradient pytrees;
  - `trimmed_mean(trim=0)` degrades to the mean (the traced-safe validation
    regression: the old `assert 2*trim < u` vanished under jit and said
    nothing about invalid trims anyway — bounds now live in
    `DefenseSpec.validate` / concrete-int kernel checks);
  - a defense-lane sweep reproduces the per-defense `FLTrainer.run_scan`
    digital baseline lane-for-lane (rtol 1e-6) on a showdown-style mixed
    grid, in tree-state and flat-state engines, strict mode bit-identical;
  - the `lax.switch` selector built over a code subset routes correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.core import defenses as DEF
from repro.core.aggregation import FLOAConfig
from repro.core.attacks import AttackConfig, AttackType, first_n_mask
from repro.core.channel import ChannelConfig
from repro.core.power_control import Policy, PowerConfig
from repro.core.scenario import DEFENSE_CODES, DefenseSpec
from repro.fl import (ExecutionPlan, FLTrainer, ScenarioCase, SweepEngine,
                      SweepSpec)

U = 4


def _grads_tree(seed=0, u=6):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(u, 7, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(u, 5)).astype(np.float32))}


def _flatten(tree):
    flat, _ = DEF._flatten_u(tree)
    return flat


# ------------------------------------------------- flat kernels vs pytree API


@pytest.mark.parametrize("defense,kw,flat_fn", [
    ("mean", {}, lambda f: DEF.flat_mean(f)),
    ("median", {}, lambda f: DEF.flat_median(f)),
    ("trimmed_mean", dict(trim=2), lambda f: DEF.flat_trimmed_mean(f, 2)),
    ("krum", dict(num_byzantine=1), lambda f: DEF.flat_krum(f, 1)),
    ("krum", dict(num_byzantine=1, multi=3), lambda f: DEF.flat_krum(f, 1, 3)),
    ("geometric_median", {}, lambda f: DEF.flat_geometric_median(f)),
])
def test_flat_kernel_matches_pytree_digital_aggregate(defense, kw, flat_fn):
    tree = _grads_tree()
    flat = _flatten(tree)
    got = flat_fn(flat)
    want_tree = DEF.digital_aggregate(tree, defense, **kw)
    want = jnp.concatenate([np.asarray(x, np.float32).reshape(-1)
                            for x in jax.tree_util.tree_leaves(want_tree)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_trimmed_mean_trim0_is_mean():
    """trim=0 must degrade to the plain mean (the edge the old assert's
    error message misdescribed)."""
    flat = _flatten(_grads_tree(3))
    got = DEF.flat_trimmed_mean(flat, 0)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(DEF.flat_mean(flat)),
                               rtol=1e-6, atol=1e-7)
    tree = _grads_tree(3)
    got_tree = DEF.digital_aggregate(tree, "trimmed_mean", trim=0)
    want_tree = DEF.digital_aggregate(tree, "mean")
    for g, w in zip(jax.tree_util.tree_leaves(got_tree),
                    jax.tree_util.tree_leaves(want_tree)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-7)


def test_trimmed_mean_concrete_bounds_raise():
    flat = _flatten(_grads_tree())  # U=6
    for bad in (-1, 3, 7):
        with pytest.raises(ValueError, match="trim"):
            DEF.flat_trimmed_mean(flat, bad)


def test_trimmed_mean_traced_trim_jits():
    """The kernel must accept a TRACED trim (the sweep's per-lane int32):
    under jit there is no concrete value to assert on — bounds live in the
    config layer."""
    flat = _flatten(_grads_tree())
    f = jax.jit(DEF.flat_trimmed_mean)
    got = f(flat, jnp.int32(2))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(DEF.flat_trimmed_mean(flat, 2)),
                               rtol=1e-6, atol=1e-7)


def test_defense_spec_validation():
    DefenseSpec(name="trimmed_mean", trim=1).validate(4)
    with pytest.raises(ValueError, match="trim"):
        DefenseSpec(name="trimmed_mean", trim=2).validate(4)
    with pytest.raises(ValueError, match="trim"):
        DefenseSpec(name="trimmed_mean", trim=-1).validate(4)
    with pytest.raises(ValueError, match="num_byzantine"):
        DefenseSpec(name="krum", num_byzantine=4).validate(4)
    with pytest.raises(ValueError, match="multi"):
        DefenseSpec(name="multi_krum", multi=9).validate(4)
    with pytest.raises(ValueError, match="unknown defense"):
        DefenseSpec(name="bulyan").validate(4)
    assert DefenseSpec.from_kwargs("krum", num_byzantine=1,
                                   multi=3).name == "multi_krum"
    assert DefenseSpec.from_kwargs("geometric_median", iters=16).gm_iters == 16
    with pytest.raises(ValueError, match="does not accept"):
        DefenseSpec.from_kwargs("median", bogus=1)
    with pytest.raises(ValueError, match="does not accept"):
        # an irrelevant-but-valid-elsewhere kwarg must not be silently
        # dropped: the caller meant a different defense
        DefenseSpec.from_kwargs("median", trim=2)


def test_krum_scores_finite():
    """Regression: the seed's `d2 + eye*inf` poisoned every off-diagonal
    distance with 0*inf = NaN, so all Krum scores were NaN and Krum silently
    returned worker 0."""
    flat = _flatten(_grads_tree())
    scores = np.asarray(DEF._krum_scores(flat, 1))
    assert np.all(np.isfinite(scores))


def test_selector_subset_routes_correctly():
    """A selector built over a code subset must route each listed code to its
    kernel and remap unlisted codes (analog lanes) to SOME valid branch."""
    flat = _flatten(_grads_tree())
    trim, f, multi = jnp.int32(1), jnp.int32(1), jnp.int32(2)
    sel = DEF.make_flat_defense_selector(
        [DEFENSE_CODES["median"], DEFENSE_CODES["multi_krum"]])
    np.testing.assert_allclose(
        np.asarray(sel(jnp.int32(DEFENSE_CODES["median"]), flat, trim, f, multi)),
        np.asarray(DEF.flat_median(flat)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sel(jnp.int32(DEFENSE_CODES["multi_krum"]), flat, trim, f, multi)),
        np.asarray(DEF.flat_krum(flat, 1, 2)), rtol=1e-6)
    out = sel(jnp.int32(0), flat, trim, f, multi)  # analog code: any branch
    assert np.all(np.isfinite(np.asarray(out)))
    # the full-default selector routes every named defense
    sel_all = DEF.make_flat_defense_selector()
    np.testing.assert_allclose(
        np.asarray(sel_all(jnp.int32(DEFENSE_CODES["trimmed_mean"]),
                           flat, trim, f, multi)),
        np.asarray(DEF.flat_trimmed_mean(flat, 1)), rtol=1e-6)


def test_selector_vmaps_over_lane_codes():
    flat = _flatten(_grads_tree())
    s = 4
    flats = jnp.stack([flat * (i + 1) for i in range(s)])
    codes = jnp.asarray([DEFENSE_CODES["mean"], DEFENSE_CODES["median"],
                         DEFENSE_CODES["krum"], DEFENSE_CODES["geometric_median"]],
                        jnp.int32)
    trims = jnp.ones((s,), jnp.int32)
    fs = jnp.ones((s,), jnp.int32)
    multis = jnp.ones((s,), jnp.int32)
    sel = DEF.make_flat_defense_selector()
    out = jax.vmap(sel)(codes, flats, trims, fs, multis)
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray(DEF.flat_median(flats[1])),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(out[3]),
                               np.asarray(DEF.flat_geometric_median(flats[3])),
                               rtol=1e-6, atol=1e-7)


# ----------------------------------------------- sweep lanes vs run_scan


def _tiny_problem(rounds=6, batch=8, d_in=6, d_h=5):
    def loss(params, b):
        pred = jax.nn.relu(b["x"] @ params["w1"]) @ params["w2"]
        return jnp.mean((pred - b["y"]) ** 2)
    k = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(k, (d_in, d_h)),
              "w2": jax.random.normal(k, (d_h, 1))}
    dim = sum(p.size for p in jax.tree_util.tree_leaves(params))
    rng = np.random.default_rng(0)
    batches = {"x": rng.normal(size=(rounds, U * batch, d_in)).astype(np.float32),
               "y": rng.normal(size=(rounds, U * batch, 1)).astype(np.float32)}
    return loss, params, dim, batches


def _floa(dim, policy, n_atk, noise=0.05, attack=AttackType.STRONGEST):
    return FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=1.0,
                              noise_std=0.0 if policy == Policy.EF else noise),
        power=PowerConfig(num_workers=U, dim=dim, p_max=1.0, policy=policy),
        attack=AttackConfig(attack=attack if n_atk else AttackType.NONE,
                            byzantine_mask=first_n_mask(U, n_atk)),
    )


DIGITAL_GRID = [
    ("mean", DefenseSpec(name="mean")),
    ("median", DefenseSpec(name="median")),
    ("trimmed_mean", DefenseSpec(name="trimmed_mean", trim=1)),
    ("krum", DefenseSpec(name="krum", num_byzantine=1)),
    ("multi_krum", DefenseSpec(name="multi_krum", num_byzantine=1, multi=2)),
    ("geometric_median", DefenseSpec(name="geometric_median")),
]


def _showdown_cases(dim, n_atk=1):
    """Mixed analog + digital grid: the showdown table in miniature."""
    cases = [ScenarioCase("bev", _floa(dim, Policy.BEV, n_atk), 0.05, seed=5),
             ScenarioCase("ci", _floa(dim, Policy.CI, n_atk), 0.05, seed=5)]
    for name, spec in DIGITAL_GRID:
        cases.append(ScenarioCase(name, _floa(dim, Policy.EF, n_atk, 0.0),
                                  0.05, seed=5, defense=spec))
    return cases


def _trainer_kwargs(spec: DefenseSpec):
    if spec.name in ("krum", "multi_krum"):
        return "krum", dict(num_byzantine=spec.num_byzantine,
                            multi=spec.multi)
    if spec.name == "trimmed_mean":
        return "trimmed_mean", dict(trim=spec.trim)
    return spec.name, {}


@pytest.mark.parametrize("flat_state", [True, False])
def test_defense_lanes_match_per_defense_run_scan(flat_state):
    """Every digital lane of a mixed showdown sweep reproduces the standalone
    per-defense FLTrainer.run_scan digital baseline (rtol 1e-6) — the
    acceptance contract for folding the showdown's digital half into the
    compiled sweep."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    cases = _showdown_cases(dim)
    res = SweepEngine(
        loss, SweepSpec.build(cases),
        plan=ExecutionPlan(flat_state=flat_state)).run(params, batches)
    for i, case in enumerate(cases):
        if not case.defense.is_digital:
            continue
        defense, dkw = _trainer_kwargs(case.defense)
        tr = FLTrainer(loss_fn=loss, floa=case.floa, alpha=case.alpha,
                       mode="digital", defense=defense, defense_kwargs=dkw)
        p_scan, logs = tr.run_scan(dict(params), batches,
                                   jax.random.PRNGKey(case.seed), eval_every=1)
        np.testing.assert_allclose(
            res.loss[i], np.asarray([l.loss for l in logs]),
            rtol=1e-6, atol=1e-7, err_msg=case.name)
        np.testing.assert_allclose(
            res.grad_norm[i], np.asarray([l.grad_norm for l in logs]),
            rtol=1e-5, atol=1e-6, err_msg=case.name)
        for k in params:
            np.testing.assert_allclose(
                np.asarray(res.params[k][i]), np.asarray(p_scan[k]),
                rtol=1e-6, atol=1e-7, err_msg=f"{case.name}.{k}")


def test_defense_lanes_strict_flat_matches_tree_bitwise():
    """strict_numerics stays bit-exact across the state representations with
    defense lanes in the grid (the digital select is shared by both paths)."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    spec = SweepSpec.build(_showdown_cases(dim))
    tree = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            flat_state=False, strict_numerics=True)).run(params, batches)
    flat = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(strict_numerics=True)).run(params, batches)
    np.testing.assert_array_equal(tree.loss, flat.loss)
    np.testing.assert_array_equal(tree.grad_norm, flat.grad_norm)
    for k in tree.params:
        np.testing.assert_array_equal(np.asarray(tree.params[k]),
                                      np.asarray(flat.params[k]))


def test_digital_run_scan_flat_matches_nonflat():
    """FLTrainer.run_scan(flat=True) now covers digital mode by delegating to
    a single defense lane; it must match the tree-state digital scan."""
    loss, params, dim, batches = _tiny_problem(rounds=5)
    tr = FLTrainer(loss_fn=loss, floa=_floa(dim, Policy.EF, 1, 0.0),
                   alpha=0.05, mode="digital", defense="krum",
                   defense_kwargs=dict(num_byzantine=1))
    key = jax.random.PRNGKey(2)
    p_tree, logs_tree = tr.run_scan(dict(params), batches, key, eval_every=1)
    p_flat, logs_flat = tr.run_scan(dict(params), batches, key, eval_every=1,
                                    flat=True)
    np.testing.assert_allclose(
        np.asarray([l.loss for l in logs_tree]),
        np.asarray([l.loss for l in logs_flat]), rtol=1e-6, atol=1e-7)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_tree[k]),
                                   np.asarray(p_flat[k]),
                                   rtol=1e-6, atol=1e-7)


def test_digital_run_scan_flat_falls_back_on_unsupported_kwargs():
    """defense_kwargs the lane axis cannot express (legacy geometric_median
    eps=...) must not break run_scan(flat=True): it silently keeps the tree
    scan, which forwards arbitrary kwargs to the pytree defense."""
    loss, params, dim, batches = _tiny_problem(rounds=4)
    tr = FLTrainer(loss_fn=loss, floa=_floa(dim, Policy.EF, 1, 0.0),
                   alpha=0.05, mode="digital", defense="geometric_median",
                   defense_kwargs=dict(eps=1e-6))
    assert tr._flat_defense() is None
    key = jax.random.PRNGKey(4)
    p_tree, logs_tree = tr.run_scan(dict(params), batches, key, eval_every=1)
    p_flat, logs_flat = tr.run_scan(dict(params), batches, key, eval_every=1,
                                    flat=True)
    np.testing.assert_array_equal(
        np.asarray([l.loss for l in logs_tree]),
        np.asarray([l.loss for l in logs_flat]))
    for k in params:
        np.testing.assert_array_equal(np.asarray(p_tree[k]),
                                      np.asarray(p_flat[k]))


def test_pure_floa_sweep_unchanged_by_defense_axis():
    """A spec with no digital lanes must trace the fused pure-FLOA path:
    trajectories are bit-identical whether or not the defense axis exists in
    the engine (guards the any_digital static routing)."""
    loss, params, dim, batches = _tiny_problem(rounds=5)
    cases = [ScenarioCase("bev", _floa(dim, Policy.BEV, 1), 0.05, seed=5),
             ScenarioCase("ci", _floa(dim, Policy.CI, 0), 0.05, seed=6)]
    spec = SweepSpec.build(cases)
    assert not spec.any_digital and spec.digital_codes == ()
    res = SweepEngine(loss, spec).run(params, batches)
    # an explicit all-floa DefenseSpec is the same sweep
    cases2 = [ScenarioCase(c.name, c.floa, c.alpha, c.seed,
                           defense=DefenseSpec(name="floa")) for c in cases]
    res2 = SweepEngine(loss, SweepSpec.build(cases2)).run(params, batches)
    np.testing.assert_array_equal(res.loss, res2.loss)


@pytest.mark.parametrize("flat_state", [True, False])
def test_all_digital_shortcut_matches_mixed_lanes(flat_state):
    """An all-digital spec takes the no-analog-leg shortcut (no stats /
    channel draw / combine traced); its trajectories must be bit-identical
    to the same digital lanes inside a mixed sweep, where the analog leg IS
    traced and discarded per lane (digital lanes never consume it)."""
    loss, params, dim, batches = _tiny_problem(rounds=5)
    mixed_cases = _showdown_cases(dim)
    digital_cases = [c for c in mixed_cases if c.defense.is_digital]
    spec = SweepSpec.build(digital_cases)
    assert spec.all_digital
    dig = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(flat_state=flat_state)).run(params, batches)
    mixed = SweepEngine(
        loss, SweepSpec.build(mixed_cases),
        plan=ExecutionPlan(flat_state=flat_state)).run(params, batches)
    for i, case in enumerate(digital_cases):
        j = mixed.index(case.name)
        np.testing.assert_array_equal(dig.loss[i], mixed.loss[j],
                                      err_msg=case.name)
        np.testing.assert_array_equal(dig.grad_norm[i], mixed.grad_norm[j],
                                      err_msg=case.name)


# ----------------------------------------------- grouped vs switch dispatch


def test_lane_groups_metadata():
    """build_lane_groups: stable within-group order, ascending group codes,
    per-group ghost padding to the shard count, and a perm/inverse pair that
    round-trips every real lane."""
    from repro.core.scenario import build_lane_groups

    codes = [0, 4, 2, 0, 2, 4, 4]
    g = build_lane_groups(codes, shards=1)
    assert g.codes == (0, 2, 4)
    assert g.perm == (0, 3, 2, 4, 1, 5, 6)  # stable partition, no ghosts
    assert g.num_ghosts == 0
    assert [g.perm[r] for _, s, e in g.local_slices for r in range(s, e)
            ] == list(g.perm)
    for i, row in enumerate(g.inverse):
        assert g.perm[row] == i

    g2 = build_lane_groups(codes, shards=2)
    assert g2.exec_lanes % 2 == 0 and g2.lanes_per_shard * 2 == g2.exec_lanes
    # group sizes 2/2/3 pad to 2/2/4 on 2 shards -> one ghost
    assert g2.num_ghosts == 1
    # every shard's local block carries the IDENTICAL static group layout,
    # and ghosts replicate a lane of the SAME group (valid family inputs)
    for code, s, e in g2.local_slices:
        for shard in range(2):
            off = shard * g2.lanes_per_shard
            assert all(codes[i] == code for i in g2.perm[off + s:off + e])
    for i, row in enumerate(g2.inverse):
        assert g2.perm[row] == i


@pytest.mark.parametrize("flat_state", [True, False])
def test_grouped_matches_switch_dispatch(flat_state):
    """The grouped (default) dispatch must reproduce the PR-3 per-lane
    lax.switch path (grouped_dispatch=False) lane-for-lane on the mixed
    showdown grid — the acceptance contract for the static lane partition."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    spec = SweepSpec.build(_showdown_cases(dim))
    grouped = SweepEngine(
        loss, spec, plan=ExecutionPlan(flat_state=flat_state)).run(
        params, batches)
    assert SweepEngine(
        loss, spec,
        plan=ExecutionPlan(flat_state=flat_state))._groups is not None
    switch = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            flat_state=flat_state,
            grouped_dispatch=False)).run(params, batches)
    np.testing.assert_allclose(grouped.loss, switch.loss,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(grouped.grad_norm, switch.grad_norm,
                               rtol=1e-5, atol=1e-6)
    for k in switch.params:
        np.testing.assert_allclose(np.asarray(grouped.params[k]),
                                   np.asarray(switch.params[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


@pytest.mark.parametrize("flat_state", [True, False])
def test_grouped_matches_switch_bitwise_strict(flat_state):
    """Under strict_numerics the grouped rewrite is BITWISE identical to the
    switch path: per-lane math is shared (same kernels, same key-split
    schedule), only which lanes trace which family changes."""
    loss, params, dim, batches = _tiny_problem(rounds=6)
    spec = SweepSpec.build(_showdown_cases(dim))
    grouped = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            flat_state=flat_state, strict_numerics=True)).run(params, batches)
    switch = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            flat_state=flat_state, grouped_dispatch=False,
            strict_numerics=True)).run(params, batches)
    np.testing.assert_array_equal(grouped.loss, switch.loss)
    np.testing.assert_array_equal(grouped.grad_norm, switch.grad_norm)
    for k in switch.params:
        np.testing.assert_array_equal(np.asarray(grouped.params[k]),
                                      np.asarray(switch.params[k]))


def test_grouped_all_digital_and_analog_fused_route():
    """Grouping engages for all-digital sweeps (several families, no analog
    group) and leaves pure-FLOA sweeps untouched (no permutation at all)."""
    loss, params, dim, batches = _tiny_problem(rounds=4)
    digital = [c for c in _showdown_cases(dim) if c.defense.is_digital]
    eng = SweepEngine(loss, SweepSpec.build(digital))
    assert eng._groups is not None
    assert all(code != 0 for code, _, _ in eng._groups.local_slices)
    grouped = eng.run(params, batches)
    switch = SweepEngine(
        loss, SweepSpec.build(digital),
        plan=ExecutionPlan(grouped_dispatch=False)).run(params, batches)
    np.testing.assert_array_equal(grouped.loss, switch.loss)
    # pure-FLOA: the defense axis (and the grouped flag) must not touch it
    floa_cases = [ScenarioCase("bev", _floa(dim, Policy.BEV, 1), 0.05, seed=5)]
    eng2 = SweepEngine(loss, SweepSpec.build(floa_cases))
    assert eng2._groups is None


def test_grouped_preserves_lane_order_and_logs():
    """SweepResult rows come back in SPEC order (the engine permutes lanes
    into group order internally and un-permutes host-side)."""
    loss, params, dim, batches = _tiny_problem(rounds=5)
    cases = _showdown_cases(dim)
    spec = SweepSpec.build(cases)
    res = SweepEngine(loss, spec).run(params, batches)
    assert res.names == spec.names
    # per-lane check against the standalone digital baseline for a lane in
    # the MIDDLE of the grid (order bugs would misattribute trajectories)
    i = res.index("krum")
    case = cases[i]
    tr = FLTrainer(loss_fn=loss, floa=case.floa, alpha=case.alpha,
                   mode="digital", defense="krum",
                   defense_kwargs=dict(num_byzantine=1, multi=1))
    _, logs = tr.run_scan(dict(params), batches,
                          jax.random.PRNGKey(case.seed), eval_every=1)
    np.testing.assert_allclose(res.loss[i],
                               np.asarray([l.loss for l in logs]),
                               rtol=1e-6, atol=1e-7)


def test_gm_iters_must_agree_across_lanes():
    loss, params, dim, batches = _tiny_problem(rounds=2)
    with pytest.raises(ValueError, match="gm_iters"):
        SweepSpec.build([
            ScenarioCase("a", _floa(dim, Policy.EF, 0, 0.0), 0.05,
                         defense=DefenseSpec(name="geometric_median",
                                             gm_iters=4)),
            ScenarioCase("b", _floa(dim, Policy.EF, 0, 0.0), 0.05,
                         defense=DefenseSpec(name="geometric_median",
                                             gm_iters=8)),
        ])
