"""Shared pytest configuration for the suite.

Hypothesis (optional — tier-1 may run without it) executes under named
profiles so failures are reproducible across machines:

  dev (default) : randomized example search, no deadline (JIT compiles
                  inside tests), print_blob so a local failure prints its
                  reproduction blob.
  ci            : everything dev has plus derandomize=True — example
                  generation is a pure function of each test, so a CI
                  failure reproduces exactly with a plain local rerun (no
                  flaky property tests in the gate).

CI jobs export HYPOTHESIS_PROFILE=ci; anything else (or unset) gets dev.
Per-test @settings decorators still apply — they override only the fields
they name, so max_examples stays per-suite while the profile controls
determinism.
"""
import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # tier-1 without the test extra: profiles are moot
    settings = None

if settings is not None:
    settings.register_profile(
        "dev", settings(deadline=None, print_blob=True))
    settings.register_profile(
        "ci", settings(deadline=None, print_blob=True, derandomize=True,
                       suppress_health_check=[HealthCheck.too_slow]))
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
