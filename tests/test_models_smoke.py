"""Per-architecture smoke tests (deliverable f): reduced variant of every
assigned architecture runs one forward + one train step on CPU; output shapes
and finiteness asserted.

Two tiers.  The slow full-zoo sweep compiles a train step per architecture
(minutes).  The FAST tier runs every round of `pytest -m "not slow"`: the
registry contract (every named config builds and reports its flat D — the
sweep engine's state-row width) plus forward-shape and finite-grad checks
for the two model families the FL engines actually flatten today, the
transformer LM lane (qwen3_4b.lm_sweep shrunk to toy dims) and the paper's
MLP, at seconds scale.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import ARCH_IDS, get_smoke
from repro.configs.registry import PAPER_MLP, flat_param_dim, get_lm_sweep
from repro.models import encdec as ED
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 33


def _batch(cfg):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.arch_type == "vlm":
        batch["embeds_prefix"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.frontend.feature_dim))
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 16, cfg.frontend.feature_dim))
    return batch


# --------------------------------------------------------------- fast tier


def test_registry_every_named_config_builds_and_reports_flat_d():
    """Every named config builds its smoke variant and reports a positive
    flat parameter count D (allocation-free shape_only init) — the width
    the sweep engine's [S, D] state row would take for that architecture."""
    dims = {}
    for arch in ARCH_IDS:
        cfg = get_smoke(arch)
        d = flat_param_dim(cfg)
        assert d > 0, arch
        dims[arch] = d
    assert len(dims) == len(ARCH_IDS) == 10
    # The paper's own MLP reports through its config dataclass (§IV:
    # 784-64-10 -> D = 50890), not the zoo's init path.
    assert PAPER_MLP.full().dim == 50890
    # The LM sweep lane sits past BOTH kernel-routing thresholds.
    assert flat_param_dim(get_lm_sweep()) >= 1 << 21


def _toy_lm_cfg():
    return dataclasses.replace(
        get_lm_sweep(), n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
        head_dim=16, d_ff=64, vocab_size=64)


def test_fast_transformer_forward_shape_and_finite_grad():
    """Tier-1 zoo coverage for the family the LM lane trains: toy-dim
    qwen3-shaped transformer, forward shape + finite nonzero grads,
    no train-step compile."""
    cfg = _toy_lm_cfg()
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 17), 0,
                              cfg.vocab_size)
    params, _ = T.init_lm(KEY, cfg)
    logits, _ = T.forward(params, toks, cfg)
    assert logits.shape == (B, 17, cfg.padded_vocab)
    loss, grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, {"tokens": toks}, cfg))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_fast_paper_mlp_forward_shape_and_finite_grad():
    """Same fast contract for the paper's MLP family (models are plain
    param pytrees; the loss is the §IV cross-entropy)."""
    from repro.models.mlp import init_mlp, mlp_loss
    cfg = PAPER_MLP.smoke()
    params = init_mlp(KEY, d_in=cfg.d_in, d_hidden=cfg.d_hidden,
                      n_classes=cfg.n_classes)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.d_in))
    y = jax.random.randint(jax.random.PRNGKey(3), (B,), 0, cfg.n_classes)
    loss, grads = jax.value_and_grad(
        lambda p: mlp_loss(p, {"x": x, "y": y}))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


# ---------------------------------------------------------- slow full zoo


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow  # compiles a train step per arch (~10-30s each)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    batch = _batch(cfg)
    if cfg.arch_type == "audio":
        params, _ = ED.init_encdec(KEY, cfg)
        loss_fn = lambda p: ED.encdec_loss(p, batch, cfg)  # noqa: E731
    else:
        params, _ = T.init_lm(KEY, cfg)
        loss_fn = lambda p: T.lm_loss(p, batch, cfg)  # noqa: E731

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    # one SGD step changes the loss and stays finite
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params)
    assert np.isfinite(float(loss2))
    assert float(loss2) != float(loss)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke(arch)
    batch = _batch(cfg)
    if cfg.arch_type == "audio":
        params, _ = ED.init_encdec(KEY, cfg)
        enc_out = ED.encode(params, batch["frames"], cfg)
        assert enc_out.shape == (B, 16, cfg.d_model)
        logits = ED.decode_full(params, batch["tokens"], enc_out, cfg)
        assert logits.shape == (B, S, cfg.padded_vocab)
    else:
        params, _ = T.init_lm(KEY, cfg)
        logits, _ = T.forward(params, batch["tokens"], cfg,
                              embeds_prefix=batch.get("embeds_prefix"))
        assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["starcoder2-3b", "deepseek-v2-236b",
                                  "mamba2-1.3b", "recurrentgemma-9b",
                                  "llama4-maverick-400b-a17b"])
@pytest.mark.slow  # compiles fwd+decode per arch (~10-20s each)
def test_smoke_decode_matches_forward(arch):
    """Step-by-step decode with caches reproduces the teacher-forced logits."""
    cfg = get_smoke(arch)
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, s), 0, cfg.vocab_size)
    params, _ = T.init_lm(KEY, cfg)
    full, _ = T.forward(params, toks, cfg)
    caches = T.init_caches(cfg, B, s)
    outs = []
    for i in range(s):
        lg, caches = T.decode_step(params, caches, toks[:, i:i + 1],
                                   jnp.int32(i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)
