"""Subprocess driver for the SIGKILL-mid-sweep resume test
(tests/test_sweep_resume.py::test_resume_after_sigkill).

Runs the SAME mixed all-axes sweep (analog BEV/CI, a Markov-fading lane
carrying the (w, h) scan tuple, a colluding cohort, and a digital median
lane exercising grouped dispatch) in one of three modes:

  full <out>        uninterrupted chunked run; SweepResult.save(out)
  ckpt <dir>        checkpointed run that SIGKILLs ITSELF right after the
                    2nd chunk-boundary checkpoint commits — simulating a
                    preemption with no chance to clean up
  resume <dir> <out>  fresh process: run(resume=True) off <dir>'s latest
                    committed checkpoint; SweepResult.save(out)

The parent asserts `full` and `ckpt`+`resume` produce bitwise-identical
SweepResults via the save/load round-trip (which this driver therefore
also exercises end to end).
"""
import dataclasses
import os
import signal
import sys

import jax

jax.config.update("jax_threefry_partitionable", True)

from repro.core.attacks import AttackType
from repro.core.power_control import Policy
from repro.core.scenario import DefenseSpec
from repro.fl import ExecutionPlan, ScenarioCase, SweepEngine, SweepSpec

from sweep_testlib import floa, tiny_problem

ROUNDS = 10
CHUNK = 2
KILL_AFTER_SAVES = 2  # SIGKILL right after the 2nd checkpoint commits


def _with_rho(cfg, rho):
    return dataclasses.replace(
        cfg, channel=dataclasses.replace(cfg.channel, markov_rho=rho))


def build_problem():
    loss, params, dim, batches = tiny_problem(rounds=ROUNDS)
    cases = [
        ScenarioCase("bev", floa(dim, Policy.BEV, 1), 0.05, seed=400),
        ScenarioCase("markov", _with_rho(floa(dim, Policy.BEV, 1), 0.9),
                     0.05, seed=401),
        ScenarioCase("collude",
                     floa(dim, Policy.CI, 2, attack=AttackType.COLLUDING),
                     0.05, seed=402),
        ScenarioCase("median", floa(dim, Policy.EF, 1, 0.0), 0.05, seed=403,
                     defense=DefenseSpec(name="median")),
    ]
    eval_fn = lambda p: {"accuracy": jax.numpy.mean(p["w1"])}
    return loss, params, batches, SweepSpec.build(cases), eval_fn


def make_engine(loss, spec, eval_fn, checkpoint_dir=None):
    plan = ExecutionPlan(chunk_rounds=CHUNK, checkpoint_dir=checkpoint_dir)
    return SweepEngine(loss, spec, eval_fn=eval_fn, eval_every=3, plan=plan)


def main() -> None:
    mode = sys.argv[1]
    loss, params, batches, spec, eval_fn = build_problem()
    if mode == "full":
        out = sys.argv[2]
        res = make_engine(loss, spec, eval_fn).run(params, batches)
        res.save(out)
    elif mode == "ckpt":
        ckpt_dir = sys.argv[2]
        from repro.checkpoint import ckpt as ckpt_mod
        orig, count = ckpt_mod.save_pytree, [0]

        def save_then_die(*a, **k):
            r = orig(*a, **k)
            count[0] += 1
            if count[0] >= KILL_AFTER_SAVES:
                os.kill(os.getpid(), signal.SIGKILL)  # no cleanup, no atexit
            return r

        # The engine calls save_pytree through the module attribute, so
        # patching the module simulates a preemption at an exact commit.
        ckpt_mod.save_pytree = save_then_die
        make_engine(loss, spec, eval_fn, ckpt_dir).run(params, batches)
        raise SystemExit("unreachable: the sweep outlived its SIGKILL")
    elif mode == "resume":
        ckpt_dir, out = sys.argv[2], sys.argv[3]
        res = make_engine(loss, spec, eval_fn, ckpt_dir).run(
            params, batches, resume=True)
        res.save(out)
    else:
        raise SystemExit(f"unknown mode {mode!r}")


if __name__ == "__main__":
    main()
