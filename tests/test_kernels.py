"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("u", [4, 10, 32])
@pytest.mark.parametrize("d", [512, 2048, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_floa_aggregate_sweep(u, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(u * d), 4)
    coeffs = jax.random.normal(ks[0], (u,))
    grads = jax.random.normal(ks[1], (u, d)).astype(dtype)
    noise = jax.random.normal(ks[2], (d,)).astype(dtype)
    bias, eps = jnp.float32(-0.2), jnp.float32(1.3)
    got = ops.floa_aggregate(coeffs, grads, noise, bias, eps, interpret=True)
    want = ops.floa_aggregate_ref(coeffs, grads, noise, bias, eps)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("s,u,d", [(1, 4, 512), (3, 10, 2048), (4, 8, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_floa_step_batched_sweep(s, u, d, dtype):
    """Fused combine+update kernel vs oracle across shapes/dtypes."""
    ks = jax.random.split(jax.random.PRNGKey(s * u + d), 7)
    w = jax.random.normal(ks[0], (s, d)).astype(dtype)
    coeffs = jax.random.normal(ks[1], (s, u))
    grads = jax.random.normal(ks[2], (s, u, d)).astype(dtype)
    noise = jax.random.normal(ks[3], (s, d)).astype(dtype)
    bias = jax.random.normal(ks[4], (s,))
    eps = jax.random.normal(ks[5], (s,))
    alpha = jax.random.uniform(ks[6], (s,), minval=0.01, maxval=0.2)
    wn, gg = ops.floa_step_batched(w, coeffs, grads, noise, bias, eps, alpha,
                                   interpret=True)
    wr, gr = ops.floa_step_batched_ref(w, coeffs, grads, noise, bias, eps,
                                       alpha)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(wn, np.float32),
                               np.asarray(wr, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(gg, np.float32),
                               np.asarray(gr, np.float32), rtol=tol, atol=tol)


def test_floa_step_ref_is_combine_plus_update():
    """The fused oracle decomposes exactly into combine oracle + PS update."""
    s, u, d = 3, 6, 777
    ks = jax.random.split(jax.random.PRNGKey(11), 7)
    w = jax.random.normal(ks[0], (s, d))
    coeffs = jax.random.normal(ks[1], (s, u))
    grads = jax.random.normal(ks[2], (s, u, d))
    noise = jax.random.normal(ks[3], (s, d))
    bias = jax.random.normal(ks[4], (s,))
    eps = jax.random.normal(ks[5], (s,))
    alpha = jax.random.uniform(ks[6], (s,))
    wn, gg = ops.floa_step_batched_ref(w, coeffs, grads, noise, bias, eps,
                                       alpha)
    want_g = ops.floa_aggregate_batched_ref(coeffs, grads, noise, bias, eps)
    np.testing.assert_array_equal(np.asarray(gg), np.asarray(want_g))
    np.testing.assert_array_equal(np.asarray(wn),
                                  np.asarray(w - alpha[:, None] * want_g))


@pytest.mark.parametrize("d,tile_d", [(300, 128), (5000, 2048), (129, 128),
                                      (127, 128)])
def test_batched_kernel_pads_non_multiple_d(d, tile_d):
    """Regression: D not a multiple of TILE_D is padded ONCE outside the
    jitted core (an earlier version recursed back into the jitted entry with
    re-padded operands).  Interpret mode, kernel vs oracle."""
    from repro.kernels.floa_aggregate import (floa_aggregate_batched,
                                              floa_step_batched)
    s, u = 2, 5
    ks = jax.random.split(jax.random.PRNGKey(d), 7)
    w = jax.random.normal(ks[0], (s, d))
    coeffs = jax.random.normal(ks[1], (s, u))
    grads = jax.random.normal(ks[2], (s, u, d))
    noise = jax.random.normal(ks[3], (s, d))
    bias = jax.random.normal(ks[4], (s,))
    eps = jax.random.normal(ks[5], (s,))
    alpha = jax.random.uniform(ks[6], (s,))
    out = floa_aggregate_batched(coeffs, grads, noise, bias, eps,
                                 interpret=True, tile_d=tile_d)
    want = ops.floa_aggregate_batched_ref(coeffs, grads, noise, bias, eps)
    assert out.shape == (s, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    wn, gg = floa_step_batched(w, coeffs, grads, noise, bias, eps, alpha,
                               interpret=True, tile_d=tile_d)
    wr, gr = ops.floa_step_batched_ref(w, coeffs, grads, noise, bias, eps,
                                       alpha)
    assert wn.shape == (s, d) and gg.shape == (s, d)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gg), np.asarray(gr),
                               rtol=1e-5, atol=1e-5)


def test_floa_step_property_random_shapes():
    """Hypothesis property: kernel == oracle for arbitrary small shapes and
    tile sizes (including D < tile_d, D == tile_d, D % tile_d != 0)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.kernels.floa_aggregate import floa_step_batched

    @settings(max_examples=10, deadline=None)
    @given(s=st.integers(1, 4), u=st.integers(1, 8), d=st.integers(1, 600),
           tile_p=st.integers(0, 2), seed=st.integers(0, 2**31 - 1))
    def prop(s, u, d, tile_p, seed):
        tile_d = 128 * (2 ** tile_p)
        ks = jax.random.split(jax.random.PRNGKey(seed), 7)
        w = jax.random.normal(ks[0], (s, d))
        coeffs = jax.random.normal(ks[1], (s, u))
        grads = jax.random.normal(ks[2], (s, u, d))
        noise = jax.random.normal(ks[3], (s, d))
        bias = jax.random.normal(ks[4], (s,))
        eps = jax.random.normal(ks[5], (s,))
        alpha = jax.random.uniform(ks[6], (s,))
        wn, gg = floa_step_batched(w, coeffs, grads, noise, bias, eps, alpha,
                                   interpret=True, tile_d=tile_d)
        wr, gr = ops.floa_step_batched_ref(w, coeffs, grads, noise, bias,
                                           eps, alpha)
        np.testing.assert_allclose(np.asarray(wn), np.asarray(wr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gg), np.asarray(gr),
                                   rtol=1e-4, atol=1e-4)

    prop()


@pytest.mark.parametrize("u,d", [(4, 256), (10, 2048), (16, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grad_stats_sweep(u, d, dtype):
    g = (jax.random.normal(jax.random.PRNGKey(u + d), (u, d)) * 0.7).astype(dtype)
    got = ops.grad_stats(g, interpret=True)
    want = ops.grad_stats_ref(g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("b,h,kv,dh,s", [
    (1, 4, 1, 64, 512),     # MQA
    (2, 8, 2, 64, 1024),    # GQA
    (2, 8, 8, 128, 777),    # MHA, ragged length
    (1, 16, 4, 128, 2048),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kv, dh, s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, h, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh)).astype(dtype)
    pos = jnp.int32(s - 3)
    got = ops.decode_attention(q, k, v, pos, interpret=True)
    want = ops.decode_attention_ref(q, k, v, pos)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_decode_attention_masks_future():
    """Entries beyond pos must not affect the output."""
    b, h, kv, dh, s = 1, 4, 2, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    pos = jnp.int32(100)
    out1 = ops.decode_attention(q, k, v, pos, interpret=True)
    k2 = k.at[:, 101:].set(99.0)
    v2 = v.at[:, 101:].set(-99.0)
    out2 = ops.decode_attention(q, k2, v2, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ------------------------------------------------ threshold-routing contracts
# The production engines auto-route to the Pallas kernels on TPU once the
# flat gradient crosses a static size threshold (BATCHED_KERNEL_MIN_D = 2^16
# for the fused FLOA step, SORT_KERNEL_MIN_D = 2^14 for the screening sort).
# The LM sweep lane (D ~ 3e6) lives far past both, so the kernel == oracle
# contract is pinned at D just below / at / above each threshold — the exact
# sizes where a routing regression would flip the implementation.


@pytest.mark.parametrize("d", [(1 << 16) - 1, 1 << 16, (1 << 16) + 1])
def test_floa_step_batched_kernel_oracle_at_routing_threshold(d):
    from repro.core.aggregation import BATCHED_KERNEL_MIN_D, batched_floa_step
    assert BATCHED_KERNEL_MIN_D == 1 << 16
    s, u = 2, 3
    ks = jax.random.split(jax.random.PRNGKey(d), 7)
    w = jax.random.normal(ks[0], (s, d))
    coeffs = jax.random.normal(ks[1], (s, u))
    grads = jax.random.normal(ks[2], (s, u, d))
    noise = jax.random.normal(ks[3], (s, d))
    bias = jax.random.normal(ks[4], (s,))
    eps = jax.random.normal(ks[5], (s,))
    alpha = jax.random.uniform(ks[6], (s,), minval=0.01, maxval=0.2)
    wn, gg = batched_floa_step(w, alpha, coeffs, grads, noise, bias, eps,
                               use_kernel=True, interpret=True)
    wr, gr = batched_floa_step(w, alpha, coeffs, grads, noise, bias, eps,
                               use_kernel=False)
    assert wn.shape == (s, d) and gg.shape == (s, d)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gg), np.asarray(gr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d", [(1 << 14) - 1, 1 << 14, (1 << 14) + 1,
                               (1 << 16) - 1, 1 << 16, (1 << 16) + 1])
def test_grad_stats_kernel_oracle_at_routing_thresholds(d):
    """The standardization-stats kernel feeds the same engines, so its
    oracle contract is pinned across both routing thresholds too."""
    u = 6
    g = jax.random.normal(jax.random.PRNGKey(d), (u, d)) * 0.7
    got = ops.grad_stats(g, interpret=True)
    want = ops.grad_stats_ref(g)
    assert got.shape == (u, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("u", [8, 64])   # unrolled network / bitonic stages
@pytest.mark.parametrize("d", [(1 << 14) - 1, 1 << 14, (1 << 14) + 1])
def test_sorted_columns_kernel_oracle_at_routing_threshold(u, d):
    from repro.core.defenses import SORT_KERNEL_MIN_D, sorted_columns
    assert SORT_KERNEL_MIN_D == 1 << 14
    x = jax.random.normal(jax.random.PRNGKey(u + d), (u, d))
    got = sorted_columns(x, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.sort(x, axis=0)))


def test_routing_predicate_resolves_off_tpu():
    """use_kernel=None must resolve False off-TPU at ANY size (CPU hosts
    would otherwise drop into interpret-mode Pallas on the hot path); the
    oracle route is the same function the kernels are pinned against."""
    if jax.default_backend() == "tpu":
        pytest.skip("predicate under test is the off-TPU resolution")
    from repro.core.aggregation import batched_floa_combine
    from repro.core.defenses import sorted_columns
    from repro.kernels import ref
    s, u, d = 1, 3, (1 << 16) + 5
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    coeffs = jax.random.normal(ks[0], (s, u))
    grads = jax.random.normal(ks[1], (s, u, d))
    noise = jax.random.normal(ks[2], (s, d))
    bias = jax.random.normal(ks[3], (s,))
    eps = jax.random.normal(ks[4], (s,))
    np.testing.assert_array_equal(
        np.asarray(batched_floa_combine(coeffs, grads, noise, bias, eps)),
        np.asarray(ref.floa_aggregate_batched_ref(coeffs, grads, noise,
                                                  bias, eps)))
    x = grads[0, :, : (1 << 14) + 5]
    np.testing.assert_array_equal(np.asarray(sorted_columns(x)),
                                  np.asarray(jnp.sort(x, axis=0)))
