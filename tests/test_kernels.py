"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("u", [4, 10, 32])
@pytest.mark.parametrize("d", [512, 2048, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_floa_aggregate_sweep(u, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(u * d), 4)
    coeffs = jax.random.normal(ks[0], (u,))
    grads = jax.random.normal(ks[1], (u, d)).astype(dtype)
    noise = jax.random.normal(ks[2], (d,)).astype(dtype)
    bias, eps = jnp.float32(-0.2), jnp.float32(1.3)
    got = ops.floa_aggregate(coeffs, grads, noise, bias, eps, interpret=True)
    want = ops.floa_aggregate_ref(coeffs, grads, noise, bias, eps)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("u,d", [(4, 256), (10, 2048), (16, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grad_stats_sweep(u, d, dtype):
    g = (jax.random.normal(jax.random.PRNGKey(u + d), (u, d)) * 0.7).astype(dtype)
    got = ops.grad_stats(g, interpret=True)
    want = ops.grad_stats_ref(g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("b,h,kv,dh,s", [
    (1, 4, 1, 64, 512),     # MQA
    (2, 8, 2, 64, 1024),    # GQA
    (2, 8, 8, 128, 777),    # MHA, ragged length
    (1, 16, 4, 128, 2048),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kv, dh, s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(ks[0], (b, h, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh)).astype(dtype)
    pos = jnp.int32(s - 3)
    got = ops.decode_attention(q, k, v, pos, interpret=True)
    want = ops.decode_attention_ref(q, k, v, pos)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_decode_attention_masks_future():
    """Entries beyond pos must not affect the output."""
    b, h, kv, dh, s = 1, 4, 2, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, dh))
    k = jax.random.normal(ks[1], (b, s, kv, dh))
    v = jax.random.normal(ks[2], (b, s, kv, dh))
    pos = jnp.int32(100)
    out1 = ops.decode_attention(q, k, v, pos, interpret=True)
    k2 = k.at[:, 101:].set(99.0)
    v2 = v.at[:, 101:].set(-99.0)
    out2 = ops.decode_attention(q, k2, v2, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
