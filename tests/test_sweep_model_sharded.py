"""Model-axis-sharded sweep engine: ("model",)-sharded == unsharded.

The flat [S, D] state's (and the [S, U, D] gradient slab's) D axis shards
over a ("model",) mesh axis (`ExecutionPlan(mesh=make_sweep_mesh(n,
model_shards=M))`): D is zero-padded once, pre-jit, to a multiple of
M * TILE_D, each shard runs the OTA combine / column-wise screening on its
own column block, standardization stats psum per-shard partial sums, and
row-geometry defenses (Krum family, geometric median) all-gather full rows.
These tests pin the contract:

  - every lane's trajectory matches the unsharded engine (rtol ~1e-6), on
    1-D ("model",) meshes and composed 2-D / 3-D meshes with the "data" and
    "workers" axes, for pure-FLOA, jamming, and mixed-defense grids,
    composed with chunking and the switch dispatch reference;
  - D % (M * TILE_D) != 0 ghost columns (zero-filled, re-masked every
    round) never perturb any real coordinate;
  - under strict_numerics the engine all-gathers full rows and replays the
    unsharded reduction order verbatim — bitwise equality;
  - a model-sharded checkpointed run resumes bit-identically.

Multi-device cases need fake host devices; the CI `sweep-sharded` job runs
this module with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

(set before any jax import).  Under plain tier-1 (1 device) they skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.fl import ExecutionPlan, SweepEngine, SweepSpec
from repro.kernels.floa_aggregate import TILE_D
from repro.launch.mesh import make_sweep_mesh
from test_sweep_workers import (
    _assert_lanes_match,
    _eval_fn,
    analog_cases,
    mixed_cases,
    worker_problem,
)

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(see the CI sweep-sharded job)")


@needs_8_devices
@pytest.mark.parametrize("m", [2, 4, 8])
def test_model_sharded_matches_unsharded_analog(m):
    """Pure-FLOA grid (with a jamming lane): shard-local combine over
    column blocks + partial-sum stats == the unsharded engine, on 2-D
    ("data", "model") meshes (m < 8) and the 1-D ("model",) mesh (m == 8)."""
    u = 8
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(analog_cases(u, dim, 6, jam_lane=True))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    mesh = make_sweep_mesh(8, model_shards=m)
    sh = SweepEngine(loss, spec, eval_fn=_eval_fn,
                     plan=ExecutionPlan(mesh=mesh)).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_model_sharded_matches_unsharded_mixed_defenses():
    """Mixed analog + screening grid: column-wise defenses (median /
    trimmed-mean) run shard-local, row-geometry defenses (Krum family)
    all-gather full rows — every lane matches unsharded."""
    u = 10
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 8))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    mesh = make_sweep_mesh(8, model_shards=4)    # ("data", "model") 2x4
    sh = SweepEngine(loss, spec, eval_fn=_eval_fn,
                     plan=ExecutionPlan(mesh=mesh)).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_model_sharded_ghost_column_padding():
    """D % (M * TILE_D) != 0 — every toy D is, since D < TILE_D — pads the
    column axis with ghost zeros; the padded width and per-shard block must
    follow the M * TILE_D contract and no real coordinate may move."""
    u = 6
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    m = 4
    eng = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(4, model_shards=m)))
    eng.run(params, batches)     # builds self._ms
    assert eng._ms is not None
    assert eng._ms.d == dim and dim % (m * TILE_D) != 0
    assert eng._ms.d_pad == m * TILE_D          # one tile per shard
    assert eng._ms.d_loc == TILE_D
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    _assert_lanes_match(eng.run(params, batches), un)


@needs_8_devices
def test_model_sharded_strict_numerics_bitwise():
    """strict_numerics + model sharding: full rows are all-gathered and the
    unsharded reduction replayed — trajectories are bit-identical."""
    u = 8
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        strict_numerics=True)).run(params, batches)
    sh = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, model_shards=4),
        strict_numerics=True)).run(params, batches)
    np.testing.assert_array_equal(sh.loss, un.loss)
    np.testing.assert_array_equal(sh.grad_norm, un.grad_norm)
    for k in un.metrics:
        np.testing.assert_array_equal(sh.metrics[k], un.metrics[k])
    for sleaf, uleaf in zip(jax.tree_util.tree_leaves(sh.params),
                            jax.tree_util.tree_leaves(un.params)):
        np.testing.assert_array_equal(np.asarray(sleaf), np.asarray(uleaf))


@needs_8_devices
def test_model_sharded_three_axis_mesh_composition():
    """The full 3-D ("data", "workers", "model") 2x2x2 mesh: lane sharding,
    worker-axis psum combine, and model-axis column blocks compose in one
    shard_mapped scan and reproduce the unsharded trajectories — as does
    the ("workers", "model") mesh and the switch dispatch reference."""
    u = 8
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    full = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, worker_shards=2, model_shards=2))
    ).run(params, batches)
    _assert_lanes_match(full, un)
    wm = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, worker_shards=4, model_shards=2))
    ).run(params, batches)
    _assert_lanes_match(wm, un)
    sw = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, worker_shards=2, model_shards=2),
        grouped_dispatch=False)).run(params, batches)
    _assert_lanes_match(sw, un)


@needs_8_devices
def test_model_sharded_composes_with_chunking(tmp_path):
    """Model sharding x chunked execution x checkpoint/resume: the chunked
    model-sharded run matches the monolithic unsharded run, and a second
    engine resuming from its checkpoints reproduces it bit-identically."""
    u = 8
    loss, params, dim, batches = worker_problem(u, rounds=6)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    mesh = make_sweep_mesh(8, model_shards=2)
    plan = ExecutionPlan(mesh=mesh, chunk_rounds=2,
                         checkpoint_dir=str(tmp_path / "ck"))
    ch = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=plan
                     ).run(params, batches)
    _assert_lanes_match(ch, un)
    # The full run checkpointed every interior chunk boundary; a resuming
    # engine restores the LAST one, replays only the final chunk, and must
    # land bitwise on the uninterrupted result.
    res = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=plan
                      ).run(params, batches, resume=True)
    np.testing.assert_array_equal(res.loss, ch.loss)
    np.testing.assert_array_equal(res.grad_norm, ch.grad_norm)
    for sleaf, uleaf in zip(jax.tree_util.tree_leaves(res.params),
                            jax.tree_util.tree_leaves(ch.params)):
        np.testing.assert_array_equal(np.asarray(sleaf), np.asarray(uleaf))


def test_model_plan_validation_runs_everywhere():
    """Tier-1 (single-device) coverage: the plan rejects model_shards
    without a matching mesh, and a degenerate model_shards=1 plan is the
    plain engine (no _ModelShards machinery built)."""
    u = 4
    loss, params, dim, batches = worker_problem(u, rounds=2)
    spec = SweepSpec.build(analog_cases(u, dim, 3))
    with pytest.raises(ValueError, match="model_shards"):
        ExecutionPlan(model_shards=2)
    with pytest.raises(ValueError, match="model_shards"):
        ExecutionPlan(model_shards=2, flat_state=False)
    eng = SweepEngine(loss, spec, plan=ExecutionPlan(
        mesh=make_sweep_mesh(1)))
    un = SweepEngine(loss, spec).run(params, batches)
    assert eng._ms is None and eng.plan.model_shards == 1
    np.testing.assert_allclose(eng.run(params, batches).loss, un.loss,
                               rtol=1e-6, atol=1e-7)
