"""Checkpoint-format contract for `repro.checkpoint` (the generic pytree
API the sweep engine's preemption-safe resume persists with): byte-exact
round-trips across dtypes and container structures, `latest_step` on
partial/corrupt directories, and write atomicity (the meta manifest's
rename is the commit; failures leave no `.tmp` litter)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as CK
from repro.checkpoint import ckpt as CKM


def _markov_like_tree():
    """The shape of the sweep engine's resume carry: a (w, h) tuple state
    with a complex Markov gain element, a key schedule, nested dicts."""
    return {
        "carry": {
            "state": (jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                      (jnp.ones((3, 4, 2), jnp.complex64) * (0.5 - 2j),
                       jnp.zeros((3,), jnp.int32))),
            "keys": jax.random.split(jax.random.PRNGKey(7), 3),
        },
        "blocks": {"loss": np.linspace(0, 1, 6).reshape(2, 3)},
    }


def _assert_leaves_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype, (xa.dtype, ya.dtype)
        np.testing.assert_array_equal(xa, ya)


# ------------------------------------------------------------- round-trip


def test_roundtrip_with_template_preserves_tuples(tmp_path):
    tree = _markov_like_tree()
    CK.save_pytree(str(tmp_path), 5, tree, extra={"t_next": 10})
    got, meta = CK.restore_pytree(str(tmp_path), 5, template=tree)
    assert isinstance(got["carry"]["state"], tuple)
    assert isinstance(got["carry"]["state"][1], tuple)
    _assert_leaves_equal(tree, got)
    assert meta["extra"] == {"t_next": 10, "step": 5}
    assert meta["format_version"] == CK.FORMAT_VERSION


def test_roundtrip_path_rebuild_without_template(tmp_path):
    tree = _markov_like_tree()
    CK.save_pytree(str(tmp_path), 0, tree)
    got, _ = CK.restore_pytree(str(tmp_path))
    # No template: structure comes from the recorded paths — tuples fold
    # back as lists, dicts keep their keys, leaves stay byte-exact.
    assert isinstance(got["carry"]["state"], list)
    _assert_leaves_equal(tree, got)


def test_roundtrip_extension_and_wide_dtypes_bitwise(tmp_path):
    rng = np.random.default_rng(0)
    f32 = rng.standard_normal((5, 3)).astype(np.float32)
    tree = {
        "bf16": jnp.asarray(f32, jnp.bfloat16),
        "c64": (f32[:, :2] + 1j * f32[:, 1:]).astype(np.complex64),
        "f64": rng.standard_normal(4),
        "i32": np.arange(-3, 3, dtype=np.int32),
        "u8": np.arange(6, dtype=np.uint8),
        "b": np.array([True, False, True]),
    }
    CK.save_pytree(str(tmp_path), 1, tree)
    got, meta = CK.restore_pytree(str(tmp_path), 1, template=tree)
    _assert_leaves_equal(tree, got)
    # bfloat16 is npz-hostile: it must ride the byte-packed route and still
    # restore to the true dtype (the old format widened it to f32).
    assert "bf16" in meta["packed"]
    assert meta["dtypes"]["bf16"] == "bfloat16"
    assert np.asarray(got["bf16"]).dtype == jnp.bfloat16


def test_roundtrip_bare_leaf_and_scalar(tmp_path):
    CK.save_pytree(str(tmp_path), 2, jnp.arange(4.0))
    got, _ = CK.restore_pytree(str(tmp_path), 2)
    np.testing.assert_array_equal(np.asarray(got), np.arange(4.0))
    CK.save_pytree(str(tmp_path), 3, {"t": np.int64(12)})
    got, _ = CK.restore_pytree(str(tmp_path), 3)
    assert int(got["t"]) == 12


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no committed checkpoint"):
        CK.restore_pytree(str(tmp_path / "nowhere"))


# ------------------------------------------------------------ latest_step


def test_latest_step_empty_and_missing_dirs(tmp_path):
    assert CK.latest_step(str(tmp_path / "absent")) is None
    assert CK.latest_step(str(tmp_path)) is None


def test_latest_step_ignores_uncommitted_and_foreign_files(tmp_path):
    CK.save_pytree(str(tmp_path), 3, {"a": np.zeros(2)})
    CK.save_pytree(str(tmp_path), 10, {"a": np.ones(2)})
    # A torn write: payload present, manifest missing — not committed.
    (tmp_path / "ckpt_99.npz").write_bytes(b"torn")
    # Foreign litter that must not crash the scan.
    (tmp_path / "ckpt_abc.npz").write_bytes(b"x")
    (tmp_path / "notes.txt").write_text("hi")
    (tmp_path / "ckpt_7.meta.json").write_text("{}")  # manifest, no payload
    assert CK.latest_step(str(tmp_path)) == 10
    got, _ = CK.restore_pytree(str(tmp_path))
    np.testing.assert_array_equal(got["a"], np.ones(2))


# -------------------------------------------------------------- atomicity


def test_failed_payload_write_leaves_no_litter(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(CKM.np, "savez", boom)
    with pytest.raises(OSError, match="disk full"):
        CK.save_pytree(str(tmp_path), 4, {"a": np.zeros(3)})
    assert [f for f in os.listdir(tmp_path)] == []
    assert CK.latest_step(str(tmp_path)) is None


def test_failed_meta_write_is_not_committed(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(CKM.json, "dump", boom)
    with pytest.raises(OSError, match="disk full"):
        CK.save_pytree(str(tmp_path), 4, {"a": np.zeros(3)})
    # The payload may have landed, but without its manifest the step is
    # uncommitted and no temp files survive.
    assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]
    assert CK.latest_step(str(tmp_path)) is None


def test_meta_rename_is_the_commit_point(tmp_path):
    CK.save_pytree(str(tmp_path), 6, {"a": np.zeros(3)})
    assert json.loads(
        (tmp_path / "ckpt_6.meta.json").read_text())["extra"]["step"] == 6
    os.remove(tmp_path / "ckpt_6.meta.json")
    assert CK.latest_step(str(tmp_path)) is None


# ----------------------------------------------------- back-compat shims


def test_legacy_save_restore_shims(tmp_path):
    params = {"w": jnp.ones((3, 2)),
              "nested": {"b": jnp.arange(4, dtype=jnp.bfloat16)}}
    opt = (jnp.zeros(3), {"m": jnp.full((2,), 2.0)})
    CK.save(str(tmp_path), 42, params, opt, extra={"note": "x"})
    assert CK.latest_step(str(tmp_path)) == 42
    p2, o2, meta = CK.restore(str(tmp_path), 42, params, opt)
    assert p2["nested"]["b"].dtype == jnp.bfloat16
    assert meta["extra"]["note"] == "x"
    _assert_leaves_equal(params, p2)
    _assert_leaves_equal(opt, o2)
    assert isinstance(o2, tuple)
