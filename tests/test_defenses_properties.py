"""Hypothesis property suite for the digital screening defenses.

Pins the invariants the defense-code lane axis relies on, on the
matrix-native [U, D] kernels (core/defenses.py):

  - permutation invariance over the worker axis (screening must not care
    which uplink slot a gradient arrived in);
  - translation equivariance (aggregate(G + c) == aggregate(G) + c);
  - breakdown-point boxes: median / trimmed-mean stay inside the honest
    per-coordinate range whenever 2f < U;
  - Krum picks an honest worker under a large-norm attacker cluster (this
    property is the regression net for the seed's `eye * inf` NaN-score bug,
    which made Krum silently return worker 0);
  - geometric median: Weiszfeld is a descent method (objective no worse than
    the mean's) and converges to an approximate fixed point.

Selection-based defenses (Krum) are fp-fragile under near-tied scores, so
those properties `assume()` a score margin instead of chasing ulps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from strategies import HYPOTHESIS_REASON

pytest.importorskip("hypothesis", reason=HYPOTHESIS_REASON)
from hypothesis import assume, given, settings

jax.config.update("jax_threefry_partitionable", True)

from repro.core.defenses import (
    _krum_scores,
    flat_geometric_median,
    flat_krum,
    flat_mean,
    flat_median,
    flat_trimmed_mean,
)
from strategies import (
    attack_scales,
    byz_counts,
    dims,
    flat_grads as _flat,
    seeds,
    shifts,
    worker_counts,
)

COORDWISE = {
    "mean": lambda f: flat_mean(f),
    "median": lambda f: flat_median(f),
    "trimmed_mean": lambda f: flat_trimmed_mean(f, 1),
    "geometric_median": lambda f: flat_geometric_median(f),
}


# ------------------------------------------------------ permutation invariance


@pytest.mark.parametrize("name", sorted(COORDWISE))
@given(u=worker_counts(), d=dims(), seed=seeds())
@settings(max_examples=20, deadline=None)
def test_property_permutation_invariant(name, u, d, seed):
    flat = _flat(seed, u, d)
    perm = np.random.default_rng(seed + 1).permutation(u)
    base = np.asarray(COORDWISE[name](jnp.asarray(flat)))
    permuted = np.asarray(COORDWISE[name](jnp.asarray(flat[perm])))
    np.testing.assert_allclose(permuted, base, rtol=1e-3, atol=1e-4)


@given(u=worker_counts(4, 10), d=dims(2, 32), seed=seeds(),
       f=byz_counts(2))
@settings(max_examples=20, deadline=None)
def test_property_krum_permutation_invariant(u, d, seed, f):
    """Krum scores permute with the workers; the selected aggregate is
    permutation-invariant whenever the winner is decided by a clear margin
    (near-ties are legitimately fp-order dependent)."""
    f = min(f, u - 3)
    flat = _flat(seed, u, d)
    perm = np.random.default_rng(seed + 1).permutation(u)
    scores = np.asarray(_krum_scores(jnp.asarray(flat), f))
    scores_p = np.asarray(_krum_scores(jnp.asarray(flat[perm]), f))
    np.testing.assert_allclose(scores_p, scores[perm], rtol=1e-4, atol=1e-5)
    srt = np.sort(scores)
    assume(srt[1] - srt[0] > 1e-3 * (1.0 + srt[0]))  # unique winner
    base = np.asarray(flat_krum(jnp.asarray(flat), f))
    permuted = np.asarray(flat_krum(jnp.asarray(flat[perm]), f))
    np.testing.assert_allclose(permuted, base, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------- translation equivariance


@pytest.mark.parametrize("name", sorted(COORDWISE))
@given(u=worker_counts(), d=dims(), seed=seeds(), c=shifts())
@settings(max_examples=20, deadline=None)
def test_property_translation_equivariant(name, u, d, seed, c):
    flat = _flat(seed, u, d)
    base = np.asarray(COORDWISE[name](jnp.asarray(flat)))
    shifted = np.asarray(COORDWISE[name](jnp.asarray(flat + np.float32(c))))
    np.testing.assert_allclose(shifted, base + np.float32(c),
                               rtol=1e-3, atol=1e-3 * (1.0 + abs(c)))


@given(u=worker_counts(4, 10), d=dims(2, 32), seed=seeds(), c=shifts())
@settings(max_examples=20, deadline=None)
def test_property_krum_translation_equivariant(u, d, seed, c):
    f = 1
    flat = _flat(seed, u, d)
    scores = np.sort(np.asarray(_krum_scores(jnp.asarray(flat), f)))
    assume(scores[1] - scores[0] > 1e-3 * (1.0 + scores[0]))
    base = np.asarray(flat_krum(jnp.asarray(flat), f))
    shifted = np.asarray(flat_krum(jnp.asarray(flat + np.float32(c)), f))
    np.testing.assert_allclose(shifted, base + np.float32(c),
                               rtol=1e-3, atol=1e-3 * (1.0 + abs(c)))


# ----------------------------------------------------- breakdown-point boxes


@pytest.mark.parametrize("name", ["median", "trimmed_mean"])
@given(u=worker_counts(3, 12), d=dims(2, 32), seed=seeds(),
       f=byz_counts(5, lo=1), scale=attack_scales())
@settings(max_examples=25, deadline=None)
def test_property_breakdown_box(name, u, d, seed, f, scale):
    """With 2f < U, coordinate-wise median and trimmed-mean(trim=f) stay
    inside the honest per-coordinate range no matter what the f Byzantine
    rows contain (the Yin et al. breakdown-point guarantee)."""
    f = min(f, (u - 1) // 2)
    rng = np.random.default_rng(seed)
    flat = _flat(seed, u, d)
    flat[:f] = rng.choice([-1.0, 1.0], size=(f, d)) * scale  # arbitrary junk
    honest = flat[f:]
    if name == "median":
        out = np.asarray(flat_median(jnp.asarray(flat)))
    else:
        out = np.asarray(flat_trimmed_mean(jnp.asarray(flat), f))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    pad = 1e-5 * (1.0 + np.abs(lo) + np.abs(hi))
    assert np.all(out >= lo - pad) and np.all(out <= hi + pad)


@given(u=worker_counts(4, 12), d=dims(2, 32), seed=seeds(),
       f=byz_counts(4, lo=1), scale=attack_scales(1e2, 1e4))
@settings(max_examples=25, deadline=None)
def test_property_krum_selects_honest_under_large_norm_attacker(u, d, seed, f,
                                                                scale):
    """Krum(f) with U >= 2f+3 returns (one of) the honest workers' gradients
    when the f attackers transmit a far-away large-norm cluster.  Fails on
    the seed's NaN-score Krum, which always returned row 0 == an attacker."""
    assume(u >= 2 * f + 3)
    flat = _flat(seed, u, d) * 0.1
    flat[:f] = flat[:f] + scale  # attackers: huge offset cluster
    out = np.asarray(flat_krum(jnp.asarray(flat), f))
    honest = flat[f:]
    d2 = np.sum((honest - out[None, :]) ** 2, axis=1)
    assert float(d2.min()) < 1e-6  # out IS an honest row
    assert np.abs(out).max() < scale / 2  # and nowhere near the attackers


# ------------------------------------------------------- geometric median


@given(u=worker_counts(), d=dims(2, 32), seed=seeds())
@settings(max_examples=20, deadline=None)
def test_property_geometric_median_descends_from_mean(u, d, seed):
    """Weiszfeld is a descent method on sum_i ||g_i - z||, started at the
    mean — the objective can only improve."""
    flat = _flat(seed, u, d)
    z = np.asarray(flat_geometric_median(jnp.asarray(flat)))
    obj = lambda p: float(np.linalg.norm(flat - p[None, :], axis=1).sum())
    assert obj(z) <= obj(flat.mean(axis=0)) * (1 + 1e-5) + 1e-6


@given(u=worker_counts(), d=dims(2, 32), seed=seeds())
@settings(max_examples=20, deadline=None)
def test_property_geometric_median_weiszfeld_fixed_point(u, d, seed):
    """Enough Weiszfeld iterations reach an approximate fixed point: one more
    application of the Weiszfeld map barely moves z.  The tolerance is loose
    (1e-2 of the data scale) because Weiszfeld converges sublinearly when the
    median lands near a data point."""
    flat = _flat(seed, u, d)
    z = np.asarray(flat_geometric_median(jnp.asarray(flat), iters=64),
                   dtype=np.float64)
    dist = np.maximum(np.linalg.norm(flat.astype(np.float64) - z, axis=1),
                      1e-8)
    w = 1.0 / dist
    z_next = (w[:, None] * flat).sum(axis=0) / w.sum()
    scale = float(np.linalg.norm(flat, axis=1).mean())
    assert float(np.linalg.norm(z_next - z)) <= 1e-2 * scale + 1e-6


# ------------------------------------------------------------- blocked Krum


@given(u=worker_counts(64, 150), d=dims(2, 24),
       f=byz_counts(), seed=seeds())
@settings(max_examples=15, deadline=None)
def test_property_blocked_krum_selects_like_direct(u, d, f, seed):
    """flat_krum routes U >= KRUM_BLOCK_MIN_U through the blocked scores;
    the selected worker must match the direct formulation's argmin unless
    the two best scores are fp-tied (assume a margin, as the other Krum
    properties do)."""
    from repro.core.defenses import _krum_scores, _krum_scores_blocked
    flat = jnp.asarray(_flat(seed, u, d))
    direct = np.asarray(_krum_scores(flat, f))
    blocked = np.asarray(_krum_scores_blocked(flat, f))
    srt = np.sort(direct)
    assume(srt[1] - srt[0] > 1e-3 * max(1.0, abs(srt[0])))
    assert int(np.argmin(blocked)) == int(np.argmin(direct))
