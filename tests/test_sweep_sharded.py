"""Mesh-sharded sweep engine: sharded lanes == unsharded lanes.

The lane axis is embarrassingly parallel, so a plan with `mesh=...`
shard_maps the flat-state scan over a 1-D ("data",) mesh.  These tests pin the contract:
every real lane's trajectory matches the unsharded engine (acceptance:
allclose rtol=1e-6), including when S is not a multiple of the device count
and ghost lanes are padded in and dropped.

Multi-device cases need fake host devices; the CI `sweep-sharded` job runs
this module with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

(set before any jax import).  Under plain tier-1 (1 device) those cases skip
and only the single-device-mesh test runs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.fl import ExecutionPlan, FLTrainer, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh
from sweep_testlib import (
    defense_grid_cases as _defense_grid_cases,
    grid_cases as _grid_cases,
    tiny_problem as _tiny_problem,
)

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(see the CI sweep-sharded job)")


def _assert_lanes_match(sharded, unsharded):
    assert sharded.loss.shape == unsharded.loss.shape
    np.testing.assert_allclose(sharded.loss, unsharded.loss,
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(sharded.grad_norm, unsharded.grad_norm,
                               rtol=1e-6, atol=1e-7)
    for k in unsharded.metrics:
        np.testing.assert_allclose(sharded.metrics[k], unsharded.metrics[k],
                                   rtol=1e-6, atol=1e-7)
    for gleaf, sleaf in zip(jax.tree_util.tree_leaves(sharded.params),
                            jax.tree_util.tree_leaves(unsharded.params)):
        assert gleaf.shape == sleaf.shape
        np.testing.assert_allclose(np.asarray(gleaf), np.asarray(sleaf),
                                   rtol=1e-6, atol=1e-7)


def test_single_device_mesh_matches_unsharded():
    """A 1-device ("data",) mesh is a degenerate shard_map; trajectories must
    match the plain flat-state engine exactly.  Runs everywhere (tier-1)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 6))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    un = SweepEngine(loss, spec, eval_fn=eval_fn).run(params, batches)
    sh = SweepEngine(
        loss, spec, eval_fn=eval_fn,
        plan=ExecutionPlan(mesh=make_sweep_mesh(1))).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_sharded_matches_unsharded_grid16():
    """16-lane CI/BEV x attacker-count grid over 8 devices (2 lanes each)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 16))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    un = SweepEngine(loss, spec, eval_fn=eval_fn).run(params, batches)
    sh = SweepEngine(
        loss, spec, eval_fn=eval_fn,
        plan=ExecutionPlan(mesh=make_sweep_mesh(8))).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_sharded_padded_s13_matches_unsharded():
    """S=13 on 8 devices: padded to 16 with ghost lanes (replicas of the
    last scenario) that must be dropped from the returned result."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 13))
    un = SweepEngine(loss, spec).run(params, batches)
    eng = SweepEngine(loss, spec, plan=ExecutionPlan(mesh=make_sweep_mesh(8)))
    assert eng._pad == 3
    sh = eng.run(params, batches)
    assert sh.loss.shape[0] == 13  # ghosts dropped
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_sharded_strict_and_custom_keys():
    """Sharding composes with strict_numerics and caller-provided keys."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 8))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(8) + 42)
    un = SweepEngine(loss, spec, plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches, keys=keys)
    sh = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            strict_numerics=True,
            mesh=make_sweep_mesh(8))).run(params, batches, keys=keys)
    _assert_lanes_match(sh, un)


def test_single_device_mesh_defense_lanes_match_unsharded():
    """Defense-code lanes through a degenerate 1-device mesh == the plain
    flat-state engine.  Runs everywhere (tier-1)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_defense_grid_cases(dim, 8))
    un = SweepEngine(loss, spec).run(params, batches)
    sh = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(mesh=make_sweep_mesh(1))).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_sharded_defense_lanes_match_unsharded():
    """16-lane mixed analog+defense grid over 8 devices (2 lanes each): the
    digital screening kernels are lane-local, so sharding cannot move them."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_defense_grid_cases(dim, 16))
    un = SweepEngine(loss, spec).run(params, batches)
    sh = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(mesh=make_sweep_mesh(8))).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_sharded_defense_lane_matches_run_scan_baseline():
    """Acceptance: a sharded (8 fake devices, ghost-padded S=13) defense lane
    reproduces the standalone per-defense FLTrainer.run_scan digital baseline
    at rtol 1e-6 — the same contract the unsharded engine pins in
    tests/test_defense_lanes.py."""
    loss, params, dim, batches = _tiny_problem()
    cases = _defense_grid_cases(dim, 13)
    eng = SweepEngine(
        loss, SweepSpec.build(cases),
        plan=ExecutionPlan(mesh=make_sweep_mesh(8)))
    # Grouped dispatch pads each defense-code group to a multiple of the
    # device count (8), so the ghost count is per-group, not global.
    assert eng._groups is not None and eng._groups.shards == 8
    assert eng._groups.exec_lanes % 8 == 0
    assert eng._pad == eng._groups.num_ghosts > 0
    sh = eng.run(params, batches)
    for i, case in enumerate(cases):
        if not case.defense.is_digital:
            continue
        name = ("krum" if case.defense.name == "multi_krum"
                else case.defense.name)
        dkw = dict(trim=case.defense.trim) if name == "trimmed_mean" else (
            dict(num_byzantine=case.defense.num_byzantine,
                 multi=case.defense.multi) if name == "krum" else {})
        tr = FLTrainer(loss_fn=loss, floa=case.floa, alpha=case.alpha,
                       mode="digital", defense=name, defense_kwargs=dkw)
        _, logs = tr.run_scan(dict(params), batches,
                              jax.random.PRNGKey(case.seed), eval_every=1)
        np.testing.assert_allclose(
            sh.loss[i], np.asarray([l.loss for l in logs]),
            rtol=1e-6, atol=1e-7, err_msg=case.name)


@needs_8_devices
def test_sharded_grouped_matches_switch_s13():
    """Acceptance: grouped dispatch on 8 fake devices with S=13 (every
    defense-code group ghost-padded to a multiple of the device count) ==
    the unsharded switch-dispatch reference, rtol 1e-6 — and bitwise equal
    to the unsharded GROUPED engine under strict_numerics."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_defense_grid_cases(dim, 13))
    eng = SweepEngine(loss, spec, plan=ExecutionPlan(mesh=make_sweep_mesh(8)))
    assert eng._groups is not None and eng._groups.exec_lanes % 8 == 0
    sh = eng.run(params, batches)
    assert sh.loss.shape[0] == 13  # per-group ghosts dropped
    switch = SweepEngine(
        loss, spec, plan=ExecutionPlan(grouped_dispatch=False)).run(
        params, batches)
    _assert_lanes_match(sh, switch)

    sh_strict = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            mesh=make_sweep_mesh(8),
            strict_numerics=True)).run(params, batches)
    un_strict = SweepEngine(
        loss, spec, plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches)
    np.testing.assert_array_equal(sh_strict.loss, un_strict.loss)
    np.testing.assert_array_equal(sh_strict.grad_norm, un_strict.grad_norm)


def test_single_device_mesh_grouped_matches_switch():
    """Degenerate 1-device mesh: grouped layout with shards=1 == the plain
    switch-dispatch engine.  Runs everywhere (tier-1)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_defense_grid_cases(dim, 8))
    sh = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(mesh=make_sweep_mesh(1))).run(params, batches)
    sw = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(grouped_dispatch=False)).run(params, batches)
    _assert_lanes_match(sh, sw)


def test_mesh_requires_flat_state():
    """Deliberately exercises the deprecated per-knob kwargs: the legacy
    spelling must still warn AND route through plan validation."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 2))
    with pytest.warns(DeprecationWarning), pytest.raises(AssertionError):
        SweepEngine(loss, spec, flat_state=False, mesh=make_sweep_mesh(1))
