"""Hypothesis property tests on the FLOA system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from strategies import HYPOTHESIS_REASON

pytest.importorskip("hypothesis", reason=HYPOTHESIS_REASON)
from hypothesis import given, settings, strategies as st

jax.config.update("jax_threefry_partitionable", True)

from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    aggregate, first_n_mask, per_worker_grads,
)
from repro.core import power_control as PC
from repro.core import standardize as S
from repro.core.channel import sample_channel_gains
from strategies import byz_counts, dims, seeds, worker_counts, \
    worker_grad_tree as _grads


@given(u=worker_counts(2, 12), d=dims(8, 200), seed=seeds(999))
@settings(max_examples=30, deadline=None)
def test_property_ef_aggregate_is_exact_mean(u, d, seed):
    key = jax.random.PRNGKey(seed)
    grads_u = _grads(key, u, d)
    cfg = FLOAConfig(
        channel=ChannelConfig(num_workers=u, noise_std=0.0),
        power=PowerConfig(num_workers=u, dim=d, policy=Policy.EF),
    )
    gagg, _ = aggregate(grads_u, key, cfg)
    np.testing.assert_allclose(np.asarray(gagg["w"]),
                               np.asarray(grads_u["w"]).mean(0),
                               rtol=1e-4, atol=1e-6)


@given(u=worker_counts(2, 12), seed=seeds(999),
       pmax=st.floats(0.05, 8.0))
@settings(max_examples=40, deadline=None)
def test_property_power_constraints_hold(u, seed, pmax):
    """Every policy satisfies eq. (4): D p_i^2 <= p_max (CI in expectation
    via b0; BEV/truncated exactly)."""
    d = 64
    ch = ChannelConfig(num_workers=u, sigma=1.0)
    h = sample_channel_gains(jax.random.PRNGKey(seed), ch)
    for pol in (Policy.BEV, Policy.TRUNCATED_CI):
        pw = PowerConfig(num_workers=u, dim=d, p_max=pmax, policy=pol)
        amp = PC.transmit_amplitudes(h, pw, ch)
        assert np.all(d * np.asarray(amp) ** 2 <= pmax * (1 + 1e-5))
    # CI average-power accounting: E[b0^2/|h|^2] * D = P0max*lambda*E[1/|h|^2]
    pw = PowerConfig(num_workers=u, dim=d, p_max=pmax, policy=Policy.CI)
    b0 = float(PC.ci_b0(pw, ch))
    assert b0 > 0 and np.isfinite(b0)


@given(u=worker_counts(), n=byz_counts(), seed=seeds(99))
@settings(max_examples=30, deadline=None)
def test_property_attack_flips_make_aggregate_worse(u, n, seed):
    """The strongest attack never increases the aggregate's alignment with
    the honest mean gradient (in the noiseless channel)."""
    n = min(n, u - 1)
    d = 64
    key = jax.random.PRNGKey(seed)
    grads_u = _grads(key, u, d)
    mean_g = np.asarray(grads_u["w"]).mean(0)

    def agg(n_atk):
        cfg = FLOAConfig(
            channel=ChannelConfig(num_workers=u, noise_std=0.0),
            power=PowerConfig(num_workers=u, dim=d, policy=Policy.BEV),
            attack=AttackConfig(
                attack=AttackType.STRONGEST if n_atk else AttackType.NONE,
                byzantine_mask=first_n_mask(u, n_atk)),
        )
        g, _ = aggregate(grads_u, key, cfg)  # same key -> same channel draw
        return np.asarray(g["w"])

    align_clean = float(np.dot(agg(0).ravel(), mean_g.ravel()))
    align_atk = float(np.dot(agg(n).ravel(), mean_g.ravel()))
    assert align_atk <= align_clean + 1e-5


@given(u=worker_counts(2, 10), d=dims(16, 256), seed=seeds(99))
@settings(max_examples=30, deadline=None)
def test_property_standardized_unit_stats(u, d, seed):
    """eq. (3): standardized symbols have ~zero mean, ~unit variance when a
    worker's stats match the global stats."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (1, d)) * 2.0 + 0.7
    tree = {"w": g}
    gbar_i, eps2_i = S.per_worker_scalar_stats(tree)
    std = S.standardize(tree, gbar_i[0], eps2_i[0])
    arr = np.asarray(std["w"])
    assert abs(arr.mean()) < 1e-3
    assert abs(arr.var() - 1.0) < 1e-2


@given(seed=seeds(200))
@settings(max_examples=25, deadline=None)
def test_property_aggregate_linear_in_grads(seed):
    """The received aggregate is linear in the payload gradients for fixed
    channel/stats draws (superposition principle of the MAC)."""
    u, d = 6, 32
    key = jax.random.PRNGKey(seed)
    g1 = _grads(jax.random.fold_in(key, 1), u, d)
    cfg = FLOAConfig(
        channel=ChannelConfig(num_workers=u, noise_std=0.0),
        power=PowerConfig(num_workers=u, dim=d, policy=Policy.BEV),
    )
    a1, aux1 = aggregate(g1, key, cfg)
    g2 = {"w": g1["w"] * 2.0}
    # stats change under scaling, but honest BEV coefficients do not
    a2, aux2 = aggregate(g2, key, cfg)
    np.testing.assert_allclose(np.asarray(a2["w"]), 2 * np.asarray(a1["w"]),
                               rtol=1e-4)
