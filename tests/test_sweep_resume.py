"""Preemption-safe resume: a checkpointed chunked sweep continued with
`run(..., resume=True)` must be BITWISE identical to the uninterrupted run.

The grid is deliberately mixed across every engine axis that touches the
resume carry: flat state, grouped defense dispatch (lane permutation), a
Markov-fading lane (the (w, h) scan-carry tuple), a colluding cohort, and
an in-scan eval schedule (NaN off-schedule metrics) — all from
tests/resume_driver.py's `build_problem`, which the SIGKILL subprocess
test reuses so the in-process and killed-process contracts pin the same
computation.
"""
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro import checkpoint as CK
from repro.fl import ExecutionPlan, SweepEngine, SweepResult

import resume_driver as RD


def _assert_bitwise(a, b):
    assert a.names == b.names
    np.testing.assert_array_equal(np.asarray(a.loss), np.asarray(b.loss))
    np.testing.assert_array_equal(np.asarray(a.grad_norm),
                                  np.asarray(b.grad_norm))
    assert set(a.metrics) == set(b.metrics)
    for k in a.metrics:  # assert_array_equal treats NaN == NaN
        np.testing.assert_array_equal(np.asarray(a.metrics[k]),
                                      np.asarray(b.metrics[k]))
    for la, lb in zip(jax.tree_util.tree_leaves(a.params),
                      jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _prune_after(ckpt_dir, keep_step):
    """Simulate a preemption at `keep_step` rounds: drop every later
    checkpoint the uninterrupted run left behind."""
    for f in os.listdir(ckpt_dir):
        step = f[len("ckpt_"):].split(".")[0]
        if step.isdigit() and int(step) > keep_step:
            os.remove(os.path.join(ckpt_dir, f))
    assert CK.latest_step(str(ckpt_dir)) == keep_step


# -------------------------------------------------------------- in-process


@pytest.mark.parametrize("stop_after_rounds", [RD.CHUNK, 3 * RD.CHUNK])
def test_resume_bitwise_in_process(tmp_path, stop_after_rounds):
    """Stop after chunk k (k=1 and k=3), reload in a FRESH engine, continue:
    trajectories, metrics, and final params all bitwise-match the
    uninterrupted run — on the mixed flat+grouped+Markov grid."""
    loss, params, batches, spec, eval_fn = RD.build_problem()
    full = RD.make_engine(loss, spec, eval_fn, str(tmp_path)).run(
        params, batches)
    # Every non-final chunk boundary committed a step.
    boundaries = list(range(RD.CHUNK, RD.ROUNDS, RD.CHUNK))
    assert sorted(
        int(f[len("ckpt_"):-len(".npz")]) for f in os.listdir(tmp_path)
        if f.endswith(".npz")) == boundaries
    _prune_after(tmp_path, stop_after_rounds)
    resumed = RD.make_engine(loss, spec, eval_fn, str(tmp_path)).run(
        params, batches, resume=True)
    _assert_bitwise(full, resumed)


def test_resume_checkpoint_cadence(tmp_path):
    """checkpoint_every_chunks=2 halves the snapshots (every 2nd boundary,
    final chunk still excluded) and resume off the sparser schedule stays
    bitwise."""
    loss, params, batches, spec, eval_fn = RD.build_problem()
    plan = ExecutionPlan(chunk_rounds=RD.CHUNK, checkpoint_dir=str(tmp_path),
                         checkpoint_every_chunks=2)
    full = SweepEngine(loss, spec, eval_fn=eval_fn, eval_every=3,
                       plan=plan).run(params, batches)
    assert sorted(
        int(f[len("ckpt_"):-len(".npz")]) for f in os.listdir(tmp_path)
        if f.endswith(".npz")) == [4 * k for k in
                                   range(1, RD.ROUNDS // 4 + 1)
                                   if 4 * k < RD.ROUNDS]
    _prune_after(tmp_path, 4)
    resumed = SweepEngine(loss, spec, eval_fn=eval_fn, eval_every=3,
                          plan=plan).run(params, batches, resume=True)
    _assert_bitwise(full, resumed)


def test_resume_fresh_start_when_no_checkpoint(tmp_path):
    """resume=True with an empty checkpoint dir is a plain fresh run (so
    preemptible loops can pass resume=True unconditionally)."""
    loss, params, batches, spec, eval_fn = RD.build_problem()
    baseline = RD.make_engine(loss, spec, eval_fn).run(params, batches)
    resumed = RD.make_engine(loss, spec, eval_fn, str(tmp_path)).run(
        params, batches, resume=True)
    _assert_bitwise(baseline, resumed)
    assert CK.latest_step(str(tmp_path)) is not None  # and it checkpointed


def test_resume_requires_checkpoint_dir():
    loss, params, batches, spec, eval_fn = RD.build_problem()
    eng = SweepEngine(loss, spec, eval_fn=eval_fn,
                      plan=ExecutionPlan(chunk_rounds=RD.CHUNK))
    with pytest.raises(ValueError, match="resume=True needs a checkpoint"):
        eng.run(params, batches, resume=True)


def test_resume_rejects_incompatible_checkpoint(tmp_path):
    """The manifest pins rounds/chunking/lanes/eval schedule; a resume from
    an engine that disagrees must fail loudly, not drift silently."""
    loss, params, batches, spec, eval_fn = RD.build_problem()
    RD.make_engine(loss, spec, eval_fn, str(tmp_path)).run(params, batches)
    other = SweepEngine(loss, spec, eval_fn=eval_fn, eval_every=3,
                        plan=ExecutionPlan(chunk_rounds=5,
                                           checkpoint_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="incompatible"):
        other.run(params, batches, resume=True)


# --------------------------------------------------- SweepResult save/load


def test_sweep_result_save_load_roundtrip(tmp_path):
    loss, params, batches, spec, eval_fn = RD.build_problem()
    res = RD.make_engine(loss, spec, eval_fn).run(params, batches)
    path = str(tmp_path / "result")
    res.save(path)
    got = SweepResult.load(path)
    _assert_bitwise(res, got)
    assert got.names == res.names and isinstance(got.names, tuple)
    assert got.index("markov") == res.index("markov")


def test_sweep_result_load_rejects_foreign_files(tmp_path):
    CK.save_pytree(str(tmp_path), 3, {"a": np.zeros(2)})
    with pytest.raises(ValueError, match="not a saved SweepResult"):
        SweepResult.load(str(tmp_path / "ckpt_3"))


# --------------------------------------------------- SIGKILLed subprocess


@pytest.mark.slow
def test_resume_after_sigkill(tmp_path):
    """The full preemption story: a subprocess running the checkpointed
    sweep SIGKILLs itself right after its 2nd checkpoint commits (no
    cleanup, no atexit); a fresh process resumes off the surviving
    checkpoint and must reproduce the uninterrupted run bitwise.  Results
    cross the process boundary via SweepResult.save/load."""
    root = pathlib.Path(__file__).resolve().parents[1]
    driver = str(root / "tests" / "resume_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root / "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    ckpt_dir = str(tmp_path / "ckpt")
    full_out = str(tmp_path / "full")
    resumed_out = str(tmp_path / "resumed")

    def run(*args, expect_sigkill=False):
        proc = subprocess.run([sys.executable, driver, *args], env=env,
                              capture_output=True, text=True, timeout=600)
        if expect_sigkill:
            assert proc.returncode == -9, (proc.returncode, proc.stderr)
        else:
            assert proc.returncode == 0, proc.stderr
        return proc

    run("full", full_out)
    run("ckpt", ckpt_dir, expect_sigkill=True)
    # The kill landed right after the 2nd commit: that step must be the
    # latest committed state on disk.
    assert CK.latest_step(ckpt_dir) == RD.KILL_AFTER_SAVES * RD.CHUNK
    run("resume", ckpt_dir, resumed_out)
    _assert_bitwise(SweepResult.load(full_out), SweepResult.load(resumed_out))
