"""The real-model LM sweep lane: a shrunk qwen3-shaped transformer trained
on the Markov token stream THROUGH the sweep engine (configs.qwen3_4b
.lm_sweep feeding `SweepEngine` flat-state lanes — the path
examples/train_floa_lm.py drives).

Fast tier-1 tests run a ~70k-param shrink of the same config: the engine's
behavioral contract (no-attack FLOA reduces the LM loss; Thm-1 sign-flip
attackers push it UP; coordinate-median screening of the same attack
recovers descent) is scale-free, so it is pinned where it is cheap.  The
slow marker runs the production-shaped config (D ~ 3.0M — past the 2^16
fused-step and 2^14 sort kernel-routing thresholds) end to end.
"""
import dataclasses

import jax
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.configs.registry import flat_param_dim, get_lm_sweep
from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    DefenseSpec,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
)
from repro.data import stack_token_rounds
from repro.fl import ExecutionPlan, ScenarioCase, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh
from repro.models.transformer import init_lm, lm_loss

U, BATCH, SEQ, N_ATK, LR = 8, 2, 48, 3, 0.3

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(see the CI sweep-sharded job)")


def tiny_lm_cfg():
    """The lm_sweep config shrunk to D ~ 70k: same family, same blocks,
    seconds-scale on a CPU device."""
    return dataclasses.replace(
        get_lm_sweep(), n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256)


def lm_problem(cfg, rounds):
    dim = flat_param_dim(cfg)

    def floa(policy, attack, n, noise=0.05):
        return FLOAConfig(
            channel=ChannelConfig(num_workers=U, sigma=1.0,
                                  noise_std=0.0 if policy == Policy.EF
                                  else noise),
            power=PowerConfig(num_workers=U, dim=dim, p_max=1.0,
                              policy=policy),
            attack=AttackConfig(attack=attack if n else AttackType.NONE,
                                byzantine_mask=first_n_mask(U, n)))

    cases = [
        ScenarioCase("clean", floa(Policy.BEV, AttackType.NONE, 0),
                     LR, seed=1),
        ScenarioCase("signflip", floa(Policy.CI, AttackType.STRONGEST, N_ATK),
                     LR, seed=2),
        ScenarioCase("median", floa(Policy.EF, AttackType.STRONGEST, N_ATK,
                                    noise=0.0),
                     LR, seed=3, defense=DefenseSpec(name="median")),
    ]
    batches = {"tokens": stack_token_rounds(
        rounds, U * BATCH, SEQ + 1, cfg.vocab_size, seed=0)}
    params0, _ = init_lm(jax.random.PRNGKey(0), cfg)
    return (lambda p, b: lm_loss(p, b, cfg)), params0, batches, \
        SweepSpec.build(cases)


def _lane(res, name):
    return res.loss[list(res.names).index(name)]


def _check_separation(res, rounds):
    """The paper's qualitative story, lane by lane."""
    tail = max(1, rounds // 6)
    clean = _lane(res, "clean")
    atk = _lane(res, "signflip")
    med = _lane(res, "median")
    assert np.isfinite(res.loss).all()
    # No-attack FLOA makes progress on the LM objective.
    assert np.mean(clean[-tail:]) < clean[0]
    # Thm-1 sign-flip attackers degrade the undefended analog lane: it ends
    # above both its own start and the clean lane's end.
    assert np.mean(atk[-tail:]) > atk[0]
    assert np.mean(atk[-tail:]) > np.mean(clean[-tail:])
    # Median screening of the SAME attack recovers descent.
    assert np.mean(med[-tail:]) < med[0]
    assert np.mean(med[-tail:]) < np.mean(atk[-tail:])


def test_lm_lane_attack_and_screening_separation():
    """Tier-1: 30 FLOA rounds of the tiny LM in one compiled sweep — loss
    decreases clean, degrades under sign-flip, recovers under median."""
    rounds = 30
    loss, params0, batches, spec = lm_problem(tiny_lm_cfg(), rounds)
    res = SweepEngine(loss, spec).run(params0, batches)
    assert res.loss.shape == (3, rounds)
    _check_separation(res, rounds)


def test_lm_lane_chunked_matches_monolithic():
    """The LM lane composes with scan-of-chunks execution bitwise (same
    compiled round math, different dispatch granularity)."""
    rounds = 6
    loss, params0, batches, spec = lm_problem(tiny_lm_cfg(), rounds)
    mono = SweepEngine(loss, spec).run(params0, batches)
    chunked = SweepEngine(loss, spec, plan=ExecutionPlan(chunk_rounds=3)
                          ).run(params0, batches)
    np.testing.assert_array_equal(chunked.loss, mono.loss)
    np.testing.assert_array_equal(chunked.grad_norm, mono.grad_norm)


@needs_8_devices
def test_lm_lane_model_sharded_matches_unsharded():
    """The tiny LM's flat state (D ~ 70k, far from a TILE_D multiple)
    sharded over ("model",): trajectories match the unsharded engine."""
    rounds = 6
    loss, params0, batches, spec = lm_problem(tiny_lm_cfg(), rounds)
    un = SweepEngine(loss, spec).run(params0, batches)
    sh = SweepEngine(loss, spec, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, model_shards=4))).run(params0, batches)
    np.testing.assert_allclose(sh.loss, un.loss, rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(sh.grad_norm, un.grad_norm,
                               rtol=5e-5, atol=1e-5)


@pytest.mark.slow
def test_lm_lane_production_d_end_to_end():
    """The full lm_sweep config (D ~ 3.0M) through the engine: one compiled
    sweep at a D past every kernel-routing threshold, finite and ordered
    the same way as the tiny shrink."""
    cfg = get_lm_sweep()
    dim = flat_param_dim(cfg)
    assert dim >= 1 << 21
    rounds = 8
    loss, params0, batches, spec = lm_problem(cfg, rounds)
    res = SweepEngine(loss, spec).run(params0, batches)
    assert res.loss.shape == (3, rounds)
    assert np.isfinite(res.loss).all() and np.isfinite(res.grad_norm).all()
    # 8 rounds is enough for ordering, not convergence: the attacked lane
    # must already sit above the clean lane.
    assert res.loss[1, -1] > res.loss[0, -1]
