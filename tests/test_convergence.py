"""Integration tests: the paper's §IV claims, in miniature.

These are the behavioural contracts of the reproduction: CI ≈ EF benign,
BEV robust where CI breaks.  Reduced rounds/dataset keep CPU time ~1 min.
"""
import jax
import jax.numpy as jnp
import pytest

# Miniature end-to-end FL runs: ~20s of CPU training. Tier-1 CI skips them;
# the scheduled full-suite job (and local `pytest` with no -m filter) runs all.
pytestmark = pytest.mark.slow

jax.config.update("jax_threefry_partitionable", True)

from repro.configs.registry import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import FLTrainer
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

U, ROUNDS = 10, 80


@pytest.fixture(scope="module")
def setup():
    x, y = make_dataset(1200, seed=0)
    xt, yt = make_dataset(400, seed=99)
    shards = worker_split(x, y, U)
    params = init_mlp(jax.random.PRNGKey(0))
    return shards, params, jnp.asarray(xt), jnp.asarray(yt)


def run(setup, policy, n_atk, alpha_hat=0.1, rounds=ROUNDS, sigma=1.0):
    shards, params, xt, yt = setup
    d = PAPER_MLP.full().dim
    tp = theory.TheoryParams(num_workers=U, num_attackers=n_atk, dim=d,
                             sigma=sigma)
    pol = "ef" if policy == Policy.EF else policy.value
    alpha = theory.alpha_from_alpha_hat(tp, pol, alpha_hat)
    floa = FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=sigma,
                              noise_std=0.0 if policy == Policy.EF
                              else noise_std_for_snr(1.0, d, 10.0)),
        power=PowerConfig(num_workers=U, dim=d, p_max=1.0, policy=policy),
        attack=AttackConfig(
            attack=AttackType.STRONGEST if n_atk else AttackType.NONE,
            byzantine_mask=first_n_mask(U, n_atk)),
    )
    tr = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha,
                   eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt, yt)})
    sampler = FederatedSampler(shards, batch_per_worker=32, seed=1)
    _, logs = tr.run(dict(params), sampler, rounds, jax.random.PRNGKey(42),
                     eval_every=rounds - 1)
    return logs[-1]


def test_fig1_benign_ci_close_to_ef(setup):
    ef = run(setup, Policy.EF, 0)
    ci = run(setup, Policy.CI, 0)
    bev = run(setup, Policy.BEV, 0)
    assert ef.accuracy > 0.8
    assert abs(ci.accuracy - ef.accuracy) < 0.05          # CI ~ EF
    assert bev.accuracy > 0.7                             # BEV converges too
    assert bev.accuracy <= ci.accuracy + 0.03             # ... a bit behind


def test_fig4_ci_breaks_at_4_attackers_bev_survives(setup):
    ci = run(setup, Policy.CI, 4)
    bev = run(setup, Policy.BEV, 4)
    # N=4 > U/(1+sqrt(pi U)) = 1.51: CI diverges (loss explodes / chance acc)
    assert ci.accuracy < 0.35 or ci.loss > 2.0
    # BEV threshold U/2=5: still converging in the right direction
    assert bev.loss < ci.loss
    assert bev.accuracy > ci.accuracy


def test_single_attacker_bev_beats_ci(setup):
    ci = run(setup, Policy.CI, 1)
    bev = run(setup, Policy.BEV, 1)
    assert bev.accuracy >= ci.accuracy - 0.02


def test_digital_krum_defends(setup):
    """Beyond paper: in digital mode Krum screens the sign-flippers out."""
    shards, params, xt, yt = setup
    d = PAPER_MLP.full().dim
    floa = FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=1.0, noise_std=0.0),
        power=PowerConfig(num_workers=U, dim=d, p_max=1.0, policy=Policy.EF),
        attack=AttackConfig(attack=AttackType.STRONGEST,
                            byzantine_mask=first_n_mask(U, 3)),
    )
    tr = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=0.1, mode="digital",
                   defense="krum", defense_kwargs=dict(num_byzantine=3),
                   eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt, yt)})
    sampler = FederatedSampler(shards, batch_per_worker=32, seed=1)
    _, logs = tr.run(dict(params), sampler, ROUNDS, jax.random.PRNGKey(1),
                     eval_every=ROUNDS - 1)
    assert logs[-1].accuracy > 0.8
