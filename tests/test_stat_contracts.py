"""Statistical contracts of the channel and attack models.

The scenario axes only mean what the paper says they mean if the underlying
distributions do: |h| must actually be Rayleigh(sigma) (its moments feed
Thm 2/3 via eqs. 21/25), the Gauss-Markov chain must preserve that marginal
at every lag while mixing at rate rho, and every attack code must satisfy
the eq. 32 transmit-power accounting E||x_n||^2 <= p_n^max (with equality
for the max-power attacks).  Empirical moments use fixed keys and generous
sample sizes so the checks are deterministic, not flaky.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.core import attacks as A
from repro.core import channel as CH
from repro.core.power_control import Policy, PowerConfig, transmit_amplitudes

SIGMA = 1.3
N = 200_000


@pytest.fixture(scope="module")
def abs_samples():
    """[N] i.i.d. |h| draws through the canonical `rayleigh_gains` recipe."""
    sig = jnp.full((N,), SIGMA, jnp.float32)
    return np.asarray(CH.rayleigh_gains(jax.random.PRNGKey(0), sig))


def test_rayleigh_mean_abs(abs_samples):
    np.testing.assert_allclose(abs_samples.mean(), SIGMA * np.sqrt(np.pi / 2),
                               rtol=5e-3)


def test_rayleigh_mean_sq(abs_samples):
    np.testing.assert_allclose((abs_samples ** 2).mean(), 2 * SIGMA**2,
                               rtol=1e-2)


def test_rayleigh_sq_exponential_tail(abs_samples):
    """|h|^2 ~ Exp(mean 2 sigma^2): survival P(|h|^2 > t) = exp(-t/2sigma^2)."""
    sq = abs_samples ** 2
    mean = 2 * SIGMA**2
    for t in (0.5, 1.0, 2.0, 4.0):
        emp = np.mean(sq > t * mean)
        np.testing.assert_allclose(emp, np.exp(-t), rtol=0.05, atol=2e-3)


def test_expected_gain_helpers_match_moments():
    cfg = CH.ChannelConfig(num_workers=3, sigma=(0.5, 1.0, 2.0))
    np.testing.assert_allclose(CH.expected_abs_gain(cfg),
                               np.array([0.5, 1.0, 2.0]) * np.sqrt(np.pi / 2),
                               rtol=1e-6)
    np.testing.assert_allclose(CH.expected_sq_gain(cfg),
                               2 * np.array([0.5, 1.0, 2.0]) ** 2, rtol=1e-6)


def test_complex_init_marginal_is_rayleigh():
    """complex_gain_abs(complex_gain_init) has the same Rayleigh moments as
    the i.i.d. draw — the Markov chain starts in its stationary law."""
    sig = jnp.full((N,), SIGMA, jnp.float32)
    h0 = CH.complex_gain_init(jax.random.PRNGKey(1), sig)
    ab = np.asarray(CH.complex_gain_abs(h0))
    np.testing.assert_allclose(ab.mean(), SIGMA * np.sqrt(np.pi / 2),
                               rtol=5e-3)
    np.testing.assert_allclose((ab ** 2).mean(), 2 * SIGMA**2, rtol=1e-2)


def test_gauss_markov_preserves_marginal_and_mixes_at_rho():
    """After T steps at rho=0.7 the marginal is still Rayleigh(sigma) and the
    lag-1 autocorrelation of each complex component is rho."""
    rho, steps = 0.7, 6
    sig = jnp.full((N,), SIGMA, jnp.float32)
    key = jax.random.PRNGKey(2)
    h = CH.complex_gain_init(key, sig)
    for t in range(steps):
        w = CH.complex_gain_init(jax.random.fold_in(key, t + 1), sig)
        prev, h = h, CH.gauss_markov_step(h, w, rho)
    ab = np.asarray(CH.complex_gain_abs(h))
    np.testing.assert_allclose(ab.mean(), SIGMA * np.sqrt(np.pi / 2),
                               rtol=5e-3)
    np.testing.assert_allclose((ab ** 2).mean(), 2 * SIGMA**2, rtol=1e-2)
    p, c = np.asarray(prev), np.asarray(h)
    for comp in (0, 1):
        corr = np.corrcoef(p[:, comp], c[:, comp])[0, 1]
        np.testing.assert_allclose(corr, rho, atol=0.01)


def test_gauss_markov_rho0_is_innovation():
    """rho=0 returns the innovation bitwise — the i.i.d. degenerate."""
    sig = jnp.full((8,), SIGMA, jnp.float32)
    h = CH.complex_gain_init(jax.random.PRNGKey(3), sig)
    w = CH.complex_gain_init(jax.random.PRNGKey(4), sig)
    np.testing.assert_array_equal(np.asarray(CH.gauss_markov_step(h, w, 0.0)),
                                  np.asarray(w))


# ------------------------------------------------------- eq. 32 accounting

U, DIM = 4, 41


def _round_state(seed=0):
    k = jax.random.PRNGKey(seed)
    h = CH.rayleigh_gains(k, jnp.ones((U,), jnp.float32))
    gbar, eps2 = jnp.float32(0.13), jnp.float32(0.7)
    return h, gbar, eps2


def test_strongest_amplitude_meets_power_budget_exactly():
    """eq. 18/32: phat^2 * D * (gbar^2 + eps^2) == p_max — the strongest
    attacker spends exactly its budget under the accounting E||g||^2 =
    D (gbar^2 + eps^2)."""
    _, gbar, eps2 = _round_state()
    p_max = jnp.array([1.0, 2.5, 0.3, 1.0], jnp.float32)
    phat = A.strongest_attack_amplitude(p_max, DIM, gbar, eps2)
    np.testing.assert_allclose(phat**2 * DIM * (gbar**2 + eps2), p_max,
                               rtol=1e-6)


def test_colluding_transmit_power_is_p_max():
    """Each colluding member transmits sqrt(p_max/D) * d with d unit-RMS:
    ||x||^2 = (p_max/D) * ||d||^2 = p_max exactly (eq. 32 with equality).
    Uses the same unit-RMS normalization recipe as the sweep engine."""
    d = jax.random.normal(jax.random.PRNGKey(5), (DIM,), jnp.float32)
    d = d / jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(d))), 1e-20)
    p_max = 1.7
    x = jnp.sqrt(p_max / DIM) * d
    np.testing.assert_allclose(jnp.sum(x**2), p_max, rtol=1e-5)


def test_colluding_dir_weight_formula():
    """weight = eps * sum_B |h_n| sqrt(p_n/D), attackers only."""
    h, _, eps2 = _round_state()
    p_max = jnp.full((U,), 1.5, jnp.float32)
    mask = jnp.array([True, True, False, False])
    w = A.colluding_dir_weight(h, p_max, float(DIM), mask, eps2)
    expect = np.sqrt(float(eps2)) * np.sum(
        np.asarray(mask) * np.sqrt(1.5 / DIM) * np.asarray(h))
    np.testing.assert_allclose(w, expect, rtol=1e-6)


def test_omniscient_weight_is_summed_strongest_coefficient():
    """The omniscient cohort's received weight == the strongest attack's
    per-worker coefficient -eps phat |h| summed over the cohort; a cohort of
    one therefore reproduces the STRONGEST lane coefficient exactly."""
    h, gbar, eps2 = _round_state()
    p_max = jnp.ones((U,), jnp.float32)
    phat = A.strongest_attack_amplitude(p_max, float(DIM), gbar, eps2)
    for n in (1, 2, 3):
        mask = jnp.arange(U) < n
        w = A.omniscient_dir_weight(h, p_max, float(DIM), mask, gbar, eps2)
        expect = -np.sqrt(float(eps2)) * np.sum(
            np.asarray(phat * h)[:n])
        np.testing.assert_allclose(w, expect, rtol=1e-6)


def test_gaussian_jam_power_accounting():
    """GAUSSIAN attackers transmit white noise at per-entry std sqrt(p/D),
    so E||x||^2 = p_max; the received jam std aggregates |h|-scaled copies:
    jam_std^2 = eps^2 sum_B (p/D) |h|^2."""
    h, _, eps2 = _round_state()
    p_max = jnp.full((U,), 2.0, jnp.float32)
    mask = jnp.array([True, False, True, False])
    std = A.jam_std_arrays(h, p_max, float(DIM), mask, eps2)
    expect = np.sqrt(float(eps2) * np.sum(
        np.asarray(mask) * (2.0 / DIM) * np.asarray(h) ** 2))
    np.testing.assert_allclose(std, expect, rtol=1e-6)


def test_honest_protocol_power_within_budget():
    """Honest CI/BEV transmit amplitudes respect b_i^2 * D <= p_i^max (the
    standardized gradient has unit per-entry second moment)."""
    h, _, _ = _round_state()
    for policy in (Policy.CI, Policy.BEV):
        power = PowerConfig(num_workers=U, dim=DIM, p_max=1.0, policy=policy)
        chan = CH.ChannelConfig(num_workers=U, sigma=1.0)
        b = transmit_amplitudes(h, power, chan)
        assert np.all(np.asarray(b) >= 0.0)
        assert np.all(np.asarray(b**2 * DIM) <= 1.0 + 1e-6), policy


@pytest.mark.parametrize("attack", [A.AttackType.GAUSSIAN,
                                    A.AttackType.COLLUDING,
                                    A.AttackType.OMNISCIENT])
def test_no_gradient_payload_for_jamming_and_directional(attack):
    """GAUSSIAN/COLLUDING/OMNISCIENT carry no per-worker gradient payload in
    `signed_coefficients` (s=0 on the cohort) but DO incur the PS's
    de-standardization bias (they never standardized)."""
    h, gbar, eps2 = _round_state()
    power = PowerConfig(num_workers=U, dim=DIM, p_max=1.0, policy=Policy.BEV)
    chan = CH.ChannelConfig(num_workers=U, sigma=1.0)
    cfg = A.AttackConfig(attack=attack, byzantine_mask=A.first_n_mask(U, 2))
    s, bias = A.signed_coefficients(h, power, chan, cfg, gbar, eps2)
    honest_s, _ = A.signed_coefficients(
        h, power, chan, A.AttackConfig(), gbar, eps2)
    np.testing.assert_array_equal(np.asarray(s[:2]), 0.0)
    np.testing.assert_array_equal(np.asarray(s[2:]), np.asarray(honest_s[2:]))
    np.testing.assert_allclose(bias, np.sum(np.asarray(honest_s[:2])),
                               rtol=1e-6)
