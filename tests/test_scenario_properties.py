"""Hypothesis property suite for the adaptive-adversary attack axes.

Two structural properties the plain contract tests (test_scenario_axes.py)
cannot pin with single examples:

  - COLLUDING is a rank-1 perturbation: whatever the channel draw, power
    budget, or cohort composition, the difference between the attacked
    aggregate and the honest aggregate lies on ONE shared direction — the
    defining property of a colluding cohort (every member transmits the same
    unit-RMS vector).
  - OMNISCIENT dominates STRONGEST against plain FLOA-CI: knowing the
    round's honest mean lets the cohort cancel it at least as effectively
    as per-worker sign flips, so the attacked aggregate's alignment with
    the honest mean is never better (up to fp slack) under OMNISCIENT.

Both properties are checked on the branchless scenario-coefficient path the
sweep engine compiles, with the directional term applied exactly as the
engine applies it (post-combine injection).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from strategies import HYPOTHESIS_REASON

pytest.importorskip("hypothesis", reason=HYPOTHESIS_REASON)
from hypothesis import assume, given, settings

jax.config.update("jax_threefry_partitionable", True)

from repro.core import attacks as A
from repro.core import channel as CH
from strategies import byz_counts, dims, seeds, worker_counts

DIM_FLOOR = 8


def _round(seed, u, d, n_atk):
    """One round's raw materials: channel draw, honest per-worker gradients,
    round stats, cohort mask."""
    k = jax.random.PRNGKey(seed)
    h = CH.rayleigh_gains(jax.random.fold_in(k, 0),
                          jnp.ones((u,), jnp.float32))
    g = jax.random.normal(jax.random.fold_in(k, 1), (u, d)) * 0.5 + 0.1
    gbar = jnp.mean(g)
    eps2 = jnp.maximum(jnp.var(g), 1e-20)
    mask = jnp.arange(u) < n_atk
    return h, g, gbar, eps2, mask


def _honest_aggregate(h, g, mask, p_maxes, d):
    """Noiseless CI-style aggregate of the HONEST workers at amplitude
    sqrt(p/D) (the directional attacks leave honest coefficients alone, so
    any fixed honest weighting exposes the perturbation)."""
    w = jnp.where(mask, 0.0, jnp.sqrt(p_maxes / d) * h)
    return jnp.einsum("u,ud->d", w, g)


@given(u=worker_counts(4, 10), d=dims(DIM_FLOOR, 64), seed=seeds(),
       n_atk=byz_counts(4, lo=1))
@settings(max_examples=25, deadline=None)
def test_property_colluding_perturbation_is_rank_one(u, d, seed, n_atk):
    """For ANY two disjoint sub-cohorts of the colluding mask, the induced
    perturbations are parallel: the cohort transmits one shared direction,
    so varying WHO transmits only rescales the same vector."""
    n_atk = min(n_atk, u - 1)
    h, g, gbar, eps2, mask = _round(seed, u, d, n_atk)
    p_maxes = jnp.ones((u,), jnp.float32)
    dirn = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,), jnp.float32)
    dirn = dirn / jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(dirn))), 1e-20)

    def perturbation(cohort):
        w = A.colluding_dir_weight(h, p_maxes, float(d), cohort, eps2)
        return np.asarray(w * dirn, dtype=np.float64)

    full = perturbation(mask)
    # every single-member sub-cohort produces a PARALLEL perturbation
    for n in range(n_atk):
        solo = perturbation(jnp.arange(u) == n)
        cross = np.outer(full, solo) - np.outer(solo, full)
        scale = np.linalg.norm(full) * np.linalg.norm(solo) + 1e-12
        assert np.abs(cross).max() <= 1e-5 * scale
    # and the full cohort's weight is the sum of the member weights
    np.testing.assert_allclose(
        full, sum(perturbation(jnp.arange(u) == n) for n in range(n_atk)),
        rtol=1e-5, atol=1e-7)


@given(u=worker_counts(4, 10), d=dims(DIM_FLOOR, 64), seed=seeds(),
       n_atk=byz_counts(3, lo=1))
@settings(max_examples=20, deadline=None)
def test_property_omniscient_no_better_aligned_than_strongest(u, d, seed,
                                                              n_atk):
    """Against plain FLOA (no screening), the OMNISCIENT cohort is at least
    as damaging as STRONGEST in EXPECTATION: transmitting -mean(honest) at
    the eq. 18 power spends the whole budget cancelling the honest signal,
    where per-worker sign flips waste power on each attacker's gradient
    noise around the mean.  Per-realization either can win (an attacker's
    own gradient may overshoot the mean), so the property is on the
    batch-averaged alignment with the honest mean — 64 i.i.d. rounds per
    example."""
    n_atk = min(n_atk, u - 1)
    p_maxes = jnp.ones((u,), jnp.float32)
    mask = jnp.arange(u) < n_atk

    def one(k):
        h = CH.rayleigh_gains(jax.random.fold_in(k, 0),
                              jnp.ones((u,), jnp.float32))
        g = jax.random.normal(jax.random.fold_in(k, 1), (u, d)) * 0.5 + 0.1
        gbar = jnp.mean(g)
        eps2 = jnp.maximum(jnp.var(g), 1e-20)
        base = _honest_aggregate(h, g, mask, p_maxes, d)
        hmean = jnp.mean(jnp.where(~mask[:, None], g, 0.0), axis=0) \
            * (u / jnp.maximum(jnp.sum(~mask), 1))
        phat = A.strongest_attack_amplitude(p_maxes, float(d), gbar, eps2)
        sw = jnp.where(mask, -jnp.sqrt(eps2) * phat * h, 0.0)
        agg_strong = base + jnp.einsum("u,ud->d", sw, g)
        ow = A.omniscient_dir_weight(h, p_maxes, float(d), mask, gbar, eps2)
        agg_omni = base + ow * hmean
        return jnp.dot(agg_strong, hmean), jnp.dot(agg_omni, hmean)

    ks = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(seed), i))(
        jnp.arange(64))
    align_strong, align_omni = jax.vmap(one)(ks)
    ms, mo = float(jnp.mean(align_strong)), float(jnp.mean(align_omni))
    assert mo <= ms + 1e-4 * (1.0 + abs(ms))


@given(u=worker_counts(4, 10), d=dims(DIM_FLOOR, 64), seed=seeds(),
       n_atk=byz_counts(3, lo=1))
@settings(max_examples=25, deadline=None)
def test_property_omniscient_always_damages_alignment(u, d, seed, n_atk):
    """The omniscient perturbation's projection on the honest mean is always
    negative — it can only subtract honest signal, never add."""
    n_atk = min(n_atk, u - 1)
    h, g, gbar, eps2, mask = _round(seed, u, d, n_atk)
    p_maxes = jnp.ones((u,), jnp.float32)
    hmean = jnp.mean(jnp.where(~mask[:, None], g, 0.0), axis=0) \
        * (u / jnp.maximum(jnp.sum(~mask), 1))
    ow = A.omniscient_dir_weight(h, p_maxes, float(d), mask, gbar, eps2)
    proj = float(ow) * float(jnp.dot(hmean, hmean))
    assert proj <= 0.0
