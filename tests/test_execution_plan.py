"""ExecutionPlan contract: construction-time validation, the deprecated
legacy-kwargs path (warn + bitwise-equal execution), and the stable public
API surface (`repro` / `repro.fl` package-root exports).

The worker-sharding rules that need a multi-device mesh live in
tests/test_sweep_workers.py (8 fake devices); everything here runs on any
host.
"""
import warnings

import jax
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.fl import ExecutionPlan, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh

import sweep_testlib as TL


# ------------------------------------------------- construction-time rules


def test_plan_defaults():
    p = ExecutionPlan()
    assert p.flat_state and p.grouped_dispatch
    assert not p.strict_numerics and not p.async_staging
    assert p.mesh is None and p.chunk_rounds is None
    assert p.worker_shards == 1 and not p.worker_sharded
    assert p.data_shards == 1


def test_plan_chunk_rounds_validation():
    with pytest.raises(ValueError, match="chunk_rounds must be a positive"):
        ExecutionPlan(chunk_rounds=0)
    with pytest.raises(ValueError, match="chunk_rounds must be a positive"):
        ExecutionPlan(chunk_rounds=-3)
    assert ExecutionPlan(chunk_rounds=4).chunk_rounds == 4


def test_plan_async_requires_chunking():
    with pytest.raises(ValueError, match="requires chunk_rounds"):
        ExecutionPlan(async_staging=True)
    p = ExecutionPlan(chunk_rounds=2, async_staging=True)
    assert p.async_staging


def test_plan_mesh_requires_flat_state():
    # Same exception type the engine historically raised (AssertionError),
    # so callers' error handling is unchanged.
    with pytest.raises(AssertionError):
        ExecutionPlan(flat_state=False, mesh=make_sweep_mesh(1))


def test_plan_mesh_axis_names_validated():
    from jax.sharding import Mesh
    bad = Mesh(np.asarray(jax.devices()[:1]), ("lanes",))
    with pytest.raises(AssertionError):
        ExecutionPlan(mesh=bad)
    # Axis ORDER is part of the contract: ("model", "data") is rejected
    # even though both names are legal.
    bad_order = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                     ("model", "data"))
    with pytest.raises(AssertionError):
        ExecutionPlan(mesh=bad_order)
    # A 1-D ("model",) mesh is legal since the model-sharding PR; the
    # model_shards knob is derived from it.
    ok = ExecutionPlan(mesh=Mesh(np.asarray(jax.devices()[:1]), ("model",)))
    assert ok.model_shards == 1


def test_plan_worker_shards_need_matching_mesh():
    with pytest.raises(ValueError, match="worker_shards"):
        ExecutionPlan(worker_shards=4)  # no mesh at all
    with pytest.raises(ValueError, match="worker_shards"):
        ExecutionPlan(worker_shards=4, mesh=make_sweep_mesh(1))
    with pytest.raises(ValueError, match="worker_shards"):
        ExecutionPlan(worker_shards=0, mesh=make_sweep_mesh(1))


def test_plan_derives_worker_shards_from_mesh():
    p = ExecutionPlan(mesh=make_sweep_mesh(1))
    assert p.worker_shards == 1 and p.data_shards == 1


def test_plan_checkpoint_validation():
    with pytest.raises(ValueError, match="checkpoint_dir requires "
                                         "chunk_rounds"):
        ExecutionPlan(checkpoint_dir="/tmp/ck")
    with pytest.raises(ValueError, match="checkpoint_every_chunks must be"):
        ExecutionPlan(chunk_rounds=2, checkpoint_dir="/tmp/ck",
                      checkpoint_every_chunks=0)
    with pytest.raises(ValueError, match="no effect without"):
        ExecutionPlan(chunk_rounds=2, checkpoint_every_chunks=3)
    p = ExecutionPlan(chunk_rounds=2, checkpoint_dir="/tmp/ck",
                      checkpoint_every_chunks=3)
    assert p.checkpoint_dir == "/tmp/ck" and p.checkpoint_every_chunks == 3
    assert ExecutionPlan().checkpoint_dir is None


# --------------------------------------------- engine plan/legacy plumbing


def _problem():
    loss, params, dim, batches = TL.tiny_problem(rounds=3)
    spec = SweepSpec.build(TL.defense_grid_cases(dim, num=5))
    return loss, params, batches, spec


def test_engine_default_plan():
    loss, params, batches, spec = _problem()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = SweepEngine(loss, spec)  # no knobs: no deprecation warning
    assert eng.plan == ExecutionPlan()
    assert eng.flat_state and eng.mesh is None


def test_engine_legacy_kwargs_warn_and_build_plan():
    loss, params, batches, spec = _problem()
    with pytest.warns(DeprecationWarning, match="ExecutionPlan"):
        eng = SweepEngine(loss, spec, strict_numerics=True, chunk_rounds=2)
    assert eng.plan == ExecutionPlan(strict_numerics=True, chunk_rounds=2)
    assert eng.strict_numerics and eng.chunk_rounds == 2


def test_engine_rejects_plan_plus_legacy_kwargs():
    loss, params, batches, spec = _problem()
    with pytest.raises(ValueError, match="not both"):
        SweepEngine(loss, spec, plan=ExecutionPlan(), chunk_rounds=2)


def test_engine_legacy_validation_routes_through_plan():
    """The historical constructor errors (types AND messages) must survive
    the legacy -> plan translation."""
    loss, params, batches, spec = _problem()
    with pytest.raises(ValueError, match="chunk_rounds must be a positive"), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        SweepEngine(loss, spec, chunk_rounds=0)
    with pytest.raises(ValueError, match="requires chunk_rounds"), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        SweepEngine(loss, spec, async_staging=True)
    with pytest.raises(AssertionError), warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        SweepEngine(loss, spec, flat_state=False, mesh=make_sweep_mesh(1))


def test_plan_path_matches_legacy_kwargs_bitwise():
    """SweepEngine(plan=ExecutionPlan(...)) must reproduce the legacy-kwargs
    trajectories bitwise under strict_numerics — the plan is plumbing, not
    math."""
    loss, params, batches, spec = _problem()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = SweepEngine(loss, spec, strict_numerics=True,
                             chunk_rounds=2).run(params, batches)
    planned = SweepEngine(loss, spec, plan=ExecutionPlan(
        strict_numerics=True, chunk_rounds=2)).run(params, batches)
    np.testing.assert_array_equal(legacy.loss, planned.loss)
    np.testing.assert_array_equal(legacy.grad_norm, planned.grad_norm)
    for a, b in zip(jax.tree_util.tree_leaves(legacy.params),
                    jax.tree_util.tree_leaves(planned.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_sweep_accepts_plan():
    from repro.fl import run_sweep
    loss, params, batches, spec = _problem()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        base = run_sweep(loss, params, batches, spec)  # defaults: no warning
        via_plan = run_sweep(loss, params, batches, spec,
                             plan=ExecutionPlan())
    np.testing.assert_array_equal(base.loss, via_plan.loss)


def test_run_sweep_legacy_kwargs_warn_and_stay_bitwise():
    """run_sweep's loose execution kwargs are deprecated like the engine's:
    they must warn AND keep producing the exact plan-path trajectories."""
    from repro.fl import run_sweep
    loss, params, batches, spec = _problem()
    with pytest.warns(DeprecationWarning, match="run_sweep's loose"):
        legacy = run_sweep(loss, params, batches, spec, chunk_rounds=2)
    planned = run_sweep(loss, params, batches, spec,
                        plan=ExecutionPlan(chunk_rounds=2))
    np.testing.assert_array_equal(legacy.loss, planned.loss)
    np.testing.assert_array_equal(legacy.grad_norm, planned.grad_norm)
    for a, b in zip(jax.tree_util.tree_leaves(legacy.params),
                    jax.tree_util.tree_leaves(planned.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_sweep_rejects_plan_plus_legacy_kwargs():
    from repro.fl import run_sweep
    loss, params, batches, spec = _problem()
    with pytest.raises(ValueError, match="not both"):
        run_sweep(loss, params, batches, spec, plan=ExecutionPlan(),
                  chunk_rounds=2)


# ------------------------------------------------------ public API surface


def test_top_level_public_api():
    """examples/ and benchmarks/ import only this surface; it must exist
    and carry __all__."""
    import repro
    for name in ("SweepEngine", "ExecutionPlan", "SweepResult", "SweepSpec",
                 "ScenarioCase", "DefenseSpec", "FLOAConfig", "AttackConfig",
                 "AttackType", "ChannelConfig", "Policy", "PowerConfig",
                 "first_n_mask", "noise_std_for_snr", "run_sweep",
                 "FLTrainer", "RoundLog", "make_sweep_mesh",
                 "save_pytree", "restore_pytree", "latest_step",
                 "initialize_distributed", "setup_compilation_cache",
                 "fetch"):
        assert name in repro.__all__, name
        assert hasattr(repro, name), name
    import repro.fl as fl
    assert "ExecutionPlan" in fl.__all__
    from repro.configs import PAPER_MLP  # noqa: F401
    from repro.models import init_mlp, mlp_accuracy, mlp_loss  # noqa: F401


def test_examples_and_benchmarks_use_public_surface():
    """No deep-module imports in the user-facing sweep entry points: the
    examples and sweep benchmarks must only import repro package roots
    (repro, repro.fl, repro.core, repro.configs, repro.models, repro.data)."""
    import pathlib
    import re
    allowed = {"repro", "repro.fl", "repro.core", "repro.configs",
               "repro.models", "repro.data", "repro.core.theory"}
    root = pathlib.Path(__file__).resolve().parents[1]
    files = [root / "examples" / "quickstart.py",
             root / "examples" / "byzantine_showdown.py",
             root / "benchmarks" / "common.py",
             root / "benchmarks" / "defenses_bench.py",
             root / "benchmarks" / "sweep_bench.py"]
    pat = re.compile(r"^\s*from (repro[\w.]*) import", re.M)
    for f in files:
        for mod in pat.findall(f.read_text()):
            assert mod in allowed, f"{f.name}: deep import of {mod}"
