"""Coordinate-sort kernel contract: the odd-even transposition network
(kernels/defense_sort.py, interpret mode) must reproduce the `jnp.sort`
oracle exactly on finite inputs — it is a pure rewrite of an already-pinned
numerical path (the median / trimmed-mean screening sort), so the bar is
array_equal, not allclose.

Fixed-shape sweeps run everywhere; the hypothesis property suite (odd/even
U, D not a multiple of the tile, duplicated values) runs wherever the test
extra is installed (CI tier-1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import defenses as DEF
from repro.kernels import ops


@pytest.mark.parametrize("u", [1, 2, 7, 10, 16])
@pytest.mark.parametrize("d", [128, 2048, 2049, 5000])
def test_sort_columns_matches_oracle(u, d):
    x = jax.random.normal(jax.random.PRNGKey(u * d), (u, d))
    got = ops.sort_columns(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ops.sort_columns_ref(x)))


@pytest.mark.parametrize("s,u,d", [(1, 4, 300), (3, 10, 2048), (2, 9, 2177)])
def test_sort_columns_batched_via_vmap_matches_oracle(s, u, d):
    """The batched [S, U, D] route is jax.vmap over the [U, D] kernel
    (Pallas lifts the vmap into a leading grid dimension — there is no
    separate hand-written batched kernel to drift)."""
    x = jax.random.normal(jax.random.PRNGKey(s + u + d), (s, u, d))
    got = jax.vmap(lambda m: ops.sort_columns(m, interpret=True))(x)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ops.sort_columns_batched_ref(x)))


def test_sort_columns_bf16_roundtrip():
    """Non-f32 slabs sort in f32 inside the kernel and cast back; bf16
    values are exactly representable through that round-trip."""
    x = jax.random.normal(jax.random.PRNGKey(0), (10, 640)).astype(jnp.bfloat16)
    got = ops.sort_columns(x, interpret=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(ops.sort_columns_ref(x),
                                             np.float32))


def test_sort_columns_duplicates_and_presorted():
    """Ties and already-sorted columns are fixed points of the network."""
    x = jnp.asarray(np.tile(np.float32([[2.0], [2.0], [-1.0], [2.0]]),
                            (1, 257)))
    got = np.asarray(ops.sort_columns(x, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ops.sort_columns_ref(x)))
    srt = ops.sort_columns_ref(jax.random.normal(jax.random.PRNGKey(3),
                                                 (6, 384)))
    np.testing.assert_array_equal(
        np.asarray(ops.sort_columns(srt, interpret=True)), np.asarray(srt))


def test_sort_columns_vmaps():
    """The grouped defense dispatch calls the kernel under vmap over a
    group's lane axis — Pallas's batching rule must agree with the batched
    grid kernel and the oracle."""
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 10, 515))
    v = jax.vmap(lambda m: ops.sort_columns(m, interpret=True))(x)
    np.testing.assert_array_equal(np.asarray(v),
                                  np.asarray(ops.sort_columns_batched_ref(x)))


def test_sorted_columns_routing_is_overridable():
    """`defenses.sorted_columns(use_kernel=True, interpret=True)` must hit
    the kernel path off-TPU (the CI oracle contract) and default to
    jnp.sort on this backend."""
    x = jax.random.normal(jax.random.PRNGKey(5), (10, 300))
    kern = DEF.sorted_columns(x, use_kernel=True, interpret=True)
    default = DEF.sorted_columns(x)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(default))


def test_flat_median_and_trimmed_mean_consume_sorted_slab():
    """The rewritten median / trimmed-mean must still equal their jnp
    formulations (odd and even U)."""
    for u in (5, 6, 10):
        flat = jax.random.normal(jax.random.PRNGKey(u), (u, 222))
        np.testing.assert_array_equal(
            np.asarray(DEF.flat_median(flat)),
            np.asarray(jnp.median(flat, axis=0)))
        np.testing.assert_allclose(
            np.asarray(DEF.flat_trimmed_mean(flat, 1)),
            np.asarray(jnp.mean(jnp.sort(flat, axis=0)[1:-1], axis=0)),
            rtol=1e-6, atol=1e-7)


def test_sort_property_random_shapes():
    """Hypothesis property: kernel == jnp.sort for arbitrary small shapes,
    odd/even U, D not a multiple of the tile, heavy duplication (integer
    grids), and any tile size."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.kernels.defense_sort import sort_columns

    @settings(max_examples=25, deadline=None)
    @given(u=st.integers(1, 12), d=st.integers(1, 400),
           tile_p=st.integers(0, 2), dup=st.booleans(),
           seed=st.integers(0, 2**31 - 1))
    def prop(u, d, tile_p, dup, seed):
        tile_d = 128 * (2 ** tile_p)
        x = jax.random.normal(jax.random.PRNGKey(seed), (u, d))
        if dup:  # quantize to force ties in most columns
            x = jnp.round(x * 2.0) / 2.0
        got = sort_columns(x, interpret=True, tile_d=tile_d)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.sort(x, axis=0)))
        xb = x[None].repeat(2, axis=0) * jnp.asarray([1.0, -1.0])[:, None, None]
        gotb = jax.vmap(
            lambda m: sort_columns(m, interpret=True, tile_d=tile_d))(xb)
        np.testing.assert_array_equal(np.asarray(gotb),
                                      np.asarray(jnp.sort(xb, axis=1)))

    prop()
