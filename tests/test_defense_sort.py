"""Coordinate-sort kernel contract: the odd-even transposition network
(kernels/defense_sort.py, interpret mode) must reproduce the `jnp.sort`
oracle exactly on finite inputs — it is a pure rewrite of an already-pinned
numerical path (the median / trimmed-mean screening sort), so the bar is
array_equal, not allclose.

Fixed-shape sweeps run everywhere; the hypothesis property suite (odd/even
U, D not a multiple of the tile, duplicated values) runs wherever the test
extra is installed (CI tier-1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import defenses as DEF
from repro.kernels import ops


@pytest.mark.parametrize("u", [1, 2, 7, 10, 16])
@pytest.mark.parametrize("d", [128, 2048, 2049, 5000])
def test_sort_columns_matches_oracle(u, d):
    x = jax.random.normal(jax.random.PRNGKey(u * d), (u, d))
    got = ops.sort_columns(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ops.sort_columns_ref(x)))


@pytest.mark.parametrize("s,u,d", [(1, 4, 300), (3, 10, 2048), (2, 9, 2177)])
def test_sort_columns_batched_via_vmap_matches_oracle(s, u, d):
    """The batched [S, U, D] route is jax.vmap over the [U, D] kernel
    (Pallas lifts the vmap into a leading grid dimension — there is no
    separate hand-written batched kernel to drift)."""
    x = jax.random.normal(jax.random.PRNGKey(s + u + d), (s, u, d))
    got = jax.vmap(lambda m: ops.sort_columns(m, interpret=True))(x)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ops.sort_columns_batched_ref(x)))


def test_sort_columns_bf16_roundtrip():
    """Non-f32 slabs sort in f32 inside the kernel and cast back; bf16
    values are exactly representable through that round-trip."""
    x = jax.random.normal(jax.random.PRNGKey(0), (10, 640)).astype(jnp.bfloat16)
    got = ops.sort_columns(x, interpret=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(ops.sort_columns_ref(x),
                                             np.float32))


def test_sort_columns_duplicates_and_presorted():
    """Ties and already-sorted columns are fixed points of the network."""
    x = jnp.asarray(np.tile(np.float32([[2.0], [2.0], [-1.0], [2.0]]),
                            (1, 257)))
    got = np.asarray(ops.sort_columns(x, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ops.sort_columns_ref(x)))
    srt = ops.sort_columns_ref(jax.random.normal(jax.random.PRNGKey(3),
                                                 (6, 384)))
    np.testing.assert_array_equal(
        np.asarray(ops.sort_columns(srt, interpret=True)), np.asarray(srt))


def test_sort_columns_vmaps():
    """The grouped defense dispatch calls the kernel under vmap over a
    group's lane axis — Pallas's batching rule must agree with the batched
    grid kernel and the oracle."""
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 10, 515))
    v = jax.vmap(lambda m: ops.sort_columns(m, interpret=True))(x)
    np.testing.assert_array_equal(np.asarray(v),
                                  np.asarray(ops.sort_columns_batched_ref(x)))


def test_sorted_columns_routing_is_overridable():
    """`defenses.sorted_columns(use_kernel=True, interpret=True)` must hit
    the kernel path off-TPU (the CI oracle contract) and default to
    jnp.sort on this backend."""
    x = jax.random.normal(jax.random.PRNGKey(5), (10, 300))
    kern = DEF.sorted_columns(x, use_kernel=True, interpret=True)
    default = DEF.sorted_columns(x)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(default))


def test_flat_median_and_trimmed_mean_consume_sorted_slab():
    """The rewritten median / trimmed-mean must still equal their jnp
    formulations (odd and even U)."""
    for u in (5, 6, 10):
        flat = jax.random.normal(jax.random.PRNGKey(u), (u, 222))
        np.testing.assert_array_equal(
            np.asarray(DEF.flat_median(flat)),
            np.asarray(jnp.median(flat, axis=0)))
        np.testing.assert_allclose(
            np.asarray(DEF.flat_trimmed_mean(flat, 1)),
            np.asarray(jnp.mean(jnp.sort(flat, axis=0)[1:-1], axis=0)),
            rtol=1e-6, atol=1e-7)


def test_sort_property_random_shapes():
    """Hypothesis property: kernel == jnp.sort for arbitrary small shapes,
    odd/even U, D not a multiple of the tile, heavy duplication (integer
    grids), and any tile size."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.kernels.defense_sort import sort_columns

    @settings(max_examples=25, deadline=None)
    @given(u=st.integers(1, 12), d=st.integers(1, 400),
           tile_p=st.integers(0, 2), dup=st.booleans(),
           seed=st.integers(0, 2**31 - 1))
    def prop(u, d, tile_p, dup, seed):
        tile_d = 128 * (2 ** tile_p)
        x = jax.random.normal(jax.random.PRNGKey(seed), (u, d))
        if dup:  # quantize to force ties in most columns
            x = jnp.round(x * 2.0) / 2.0
        got = sort_columns(x, interpret=True, tile_d=tile_d)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.sort(x, axis=0)))
        xb = x[None].repeat(2, axis=0) * jnp.asarray([1.0, -1.0])[:, None, None]
        gotb = jax.vmap(
            lambda m: sort_columns(m, interpret=True, tile_d=tile_d))(xb)
        np.testing.assert_array_equal(np.asarray(gotb),
                                      np.asarray(jnp.sort(xb, axis=1)))

    prop()


# ------------------------------------------------- large-U bitonic successor


@pytest.mark.parametrize("u", [1, 3, 10, 33, 100, 1000])
@pytest.mark.parametrize("d", [1, 130, 515])
def test_sort_columns_bitonic_matches_oracle(u, d):
    """The bitonic stages are a pure rewrite of the same pinned path as the
    unrolled network: exact jnp.sort agreement on finite inputs, including
    non-power-of-two U (padded with +inf rows, sliced away) and off-tile D."""
    x = jax.random.normal(jax.random.PRNGKey(u * 1000 + d), (u, d))
    got = ops.sort_columns_bitonic(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ops.sort_columns_ref(x)))


def test_sort_columns_bitonic_duplicates_and_vmap():
    """Ties survive the min/max compare-exchanges, and the grouped-dispatch
    vmap route agrees with the batched oracle (same contract as the
    unrolled kernel's)."""
    x = jnp.asarray(np.tile(np.float32([[2.0], [2.0], [-1.0], [2.0], [0.0]]),
                            (1, 257)))
    np.testing.assert_array_equal(
        np.asarray(ops.sort_columns_bitonic(x, interpret=True)),
        np.asarray(ops.sort_columns_ref(x)))
    xb = jax.random.normal(jax.random.PRNGKey(7), (3, 40, 257))
    got = jax.vmap(lambda m: ops.sort_columns_bitonic(m, interpret=True))(xb)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ops.sort_columns_batched_ref(xb)))


def test_sort_columns_unrolled_guard_raises_above_bound():
    """Regression for the silent O(U^2) trace: the unrolled network must
    REFUSE U above UNROLL_MAX_U instead of tracing half a million min/max
    pairs."""
    x = jnp.zeros((ops.UNROLL_MAX_U + 1, 128))
    with pytest.raises(ValueError, match="O\\(U\\^2\\)"):
        ops.sort_columns(x, interpret=True)
    with pytest.raises(ValueError, match="O\\(U\\^2\\)"):
        from repro.kernels.defense_sort import sort_columns
        sort_columns(np.zeros((1000, 128), np.float32))


def test_sort_columns_bitonic_guard_raises_above_bound():
    """Padded U beyond BITONIC_MAX_U no longer fits a VMEM block — refuse,
    the router falls back to the jnp.sort oracle."""
    from repro.kernels.defense_sort import BITONIC_MAX_U
    x = jnp.zeros((BITONIC_MAX_U + 1, 8))
    with pytest.raises(ValueError, match="BITONIC_MAX_U"):
        ops.sort_columns_bitonic(x, interpret=True)


def test_sorted_columns_routes_by_population():
    """`defenses.sorted_columns(use_kernel=True)` must route U <= 32 to the
    unrolled network, 32 < U (pad <= 8192) to the bitonic stages, and
    larger slabs to jnp.sort — never into the unrolled trace bomb — and
    every route must agree with the oracle."""
    small = jax.random.normal(jax.random.PRNGKey(0), (10, 140))
    large = jax.random.normal(jax.random.PRNGKey(1), (64, 140))
    np.testing.assert_array_equal(
        np.asarray(DEF.sorted_columns(small, use_kernel=True,
                                      interpret=True)),
        np.asarray(jnp.sort(small, axis=0)))
    np.testing.assert_array_equal(
        np.asarray(DEF.sorted_columns(large, use_kernel=True,
                                      interpret=True)),
        np.asarray(jnp.sort(large, axis=0)))
    # Above the bitonic cap: the guard falls through to jnp.sort instead of
    # raising (use_kernel=True is a request, not a contract for huge U).
    huge = jax.random.normal(jax.random.PRNGKey(2),
                             (ops.BITONIC_MAX_U + 1, 3))
    np.testing.assert_array_equal(
        np.asarray(DEF.sorted_columns(huge, use_kernel=True,
                                      interpret=True)),
        np.asarray(jnp.sort(huge, axis=0)))


def test_bitonic_property_random_shapes():
    """Hypothesis property for the large-U path: bitonic == jnp.sort across
    odd/even/non-pow2 U spanning the unrolled bound, off-tile D, heavy
    duplication, and the vmap route."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.kernels.defense_sort import sort_columns_bitonic

    @settings(max_examples=25, deadline=None)
    @given(u=st.integers(1, 80), d=st.integers(1, 300),
           dup=st.booleans(), seed=st.integers(0, 2**31 - 1))
    def prop(u, d, dup, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (u, d))
        if dup:
            x = jnp.round(x * 2.0) / 2.0
        got = sort_columns_bitonic(x, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.sort(x, axis=0)))

    prop()


# --------------------------------------------------- blocked Krum (large U)


def _krum_flat(seed: int, u: int, d: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(u, d)) * 0.7 + 0.1).astype(np.float32)


def test_blocked_krum_scores_match_direct():
    """The blocked [B, U]-distance formulation (expanded ||a||^2 + ||b||^2
    - 2ab, clamped at 0, KRUM_BLOCK_ROWS rows at a time) must agree with
    the direct [U, U, D] broadcast scores — rtol contract, the expanded
    form reassociates the fp sums."""
    from repro.core.defenses import _krum_scores, _krum_scores_blocked
    for u, d in ((64, 37), (130, 16), (200, 8)):
        flat = jnp.asarray(_krum_flat(u * d, u, d))
        direct = np.asarray(_krum_scores(flat, 3))
        blocked = np.asarray(_krum_scores_blocked(flat, 3))
        np.testing.assert_allclose(blocked, direct, rtol=2e-4, atol=1e-3)


def test_flat_krum_blocked_route_equivalence():
    """flat_krum at U >= KRUM_BLOCK_MIN_U (blocked route — the [U, U]
    distance matrix never materializes at once) returns the same selection
    the direct-score formulation would."""
    from repro.core.defenses import (KRUM_BLOCK_MIN_U, _krum_scores,
                                     flat_krum)
    u, d, f = KRUM_BLOCK_MIN_U + 9, 12, 2
    flat = jnp.asarray(_krum_flat(5, u, d))
    got = np.asarray(flat_krum(flat, f))
    want = np.asarray(flat[int(np.argmin(np.asarray(_krum_scores(flat, f))))])
    np.testing.assert_array_equal(got, want)
    # multi-krum on the blocked route: mean of the m best-scored workers
    got_m = np.asarray(flat_krum(flat, f, multi=3))
    order = np.argsort(np.asarray(_krum_scores(flat, f)), kind="stable")[:3]
    np.testing.assert_allclose(
        got_m, np.asarray(jnp.mean(flat[jnp.asarray(order)], axis=0)),
        rtol=1e-5, atol=1e-6)


def test_sorted_columns_large_u_fallback_never_traces_a_network(
        monkeypatch, caplog):
    """Regression for the silent BITONIC_MAX_U fall-through: U > 8192 must
    route to jnp.sort WITHOUT tracing either sorting network (the unrolled
    trace at that U is a half-million-op bomb), and must say so — one log
    record per process, however many slabs fall back."""
    import logging

    def boom(*a, **k):
        raise AssertionError("a sorting-network kernel was traced for a "
                             "U > BITONIC_MAX_U slab")

    monkeypatch.setattr(ops, "sort_columns", boom)
    monkeypatch.setattr(ops, "sort_columns_bitonic", boom)
    monkeypatch.setattr(DEF, "_sort_fallback_logged", False)
    u = ops.BITONIC_MAX_U + 1
    x = jax.random.normal(jax.random.PRNGKey(0), (u, 4))
    with caplog.at_level(logging.WARNING, logger="repro.core.defenses"):
        got = DEF.sorted_columns(x, use_kernel=True, interpret=True)
        DEF.sorted_columns(x, use_kernel=True, interpret=True)  # second call
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.sort(x, axis=0)))
    records = [r for r in caplog.records if "BITONIC_MAX_U" in r.message]
    assert len(records) == 1          # log-once, not once-per-call
    assert f"U={u}" in records[0].message
