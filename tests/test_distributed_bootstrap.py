"""Multi-host bootstrap: `initialize_distributed` single-process no-op
semantics (tier-1) and the real 2-process `jax.distributed` CPU smoke
(slow; the CI sweep-sharded job runs it) — two coordinated subprocesses,
one CPU device each, gloo collectives, a process-spanning ("data",) mesh,
and a sharded sweep checked against the process-local engine."""
import os
import pathlib
import socket
import subprocess
import sys

import jax
import pytest

from repro import setup_compilation_cache
from repro.launch.distributed import initialize_distributed


def test_initialize_distributed_single_process_noop():
    """No coordinator, no env, or an explicit num_processes=1: nothing to
    bootstrap — must return False without touching the runtime."""
    assert initialize_distributed() is False
    assert initialize_distributed(num_processes=1) is False
    assert jax.process_count() == 1


def test_setup_compilation_cache_noop_without_dir(monkeypatch):
    monkeypatch.delenv("REPRO_COMPILATION_CACHE", raising=False)
    assert setup_compilation_cache() is None


def test_setup_compilation_cache_sets_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COMPILATION_CACHE", str(tmp_path))
    assert setup_compilation_cache() == str(tmp_path)
    # Explicit argument beats the environment.
    other = tmp_path / "other"
    assert setup_compilation_cache(str(other)) == str(other)


@pytest.mark.slow
def test_two_process_distributed_smoke(tmp_path):
    root = pathlib.Path(__file__).resolve().parents[1]
    driver = str(root / "tests" / "distributed_smoke_driver.py")
    # Shared checkpoint dir: the driver also exercises the multi-process
    # checkpoint/resume edge (collective fetch on both ranks, rank-0
    # write, broadcast resume step).
    ckpt_dir = str(tmp_path / "ckpts")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root / "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    # One CPU device per process (overriding any fake-device fan-out from
    # the CI job) so the 2-device mesh genuinely spans both processes.
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    procs = [subprocess.Popen([sys.executable, driver, str(port), str(rank),
                               ckpt_dir],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.STDOUT, text=True)
             for rank in range(2)]
    outs = [p.communicate(timeout=600)[0] for p in procs]
    for rank, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"rank {rank} failed:\n{out}"
        assert f"DISTRIBUTED_SMOKE_OK rank={rank}" in out, out
