"""Worker-axis-sharded sweep engine: worker-sharded == unsharded.

The [S, U, D] gradient slab's WORKER axis shards over a ("workers",) mesh
axis (`ExecutionPlan(mesh=make_sweep_mesh(n, worker_shards=W))`): each shard
computes gradients for its own ceil(U/W) workers from its slice of the
batch, the standardization handshake all-gathers per-worker scalar stats,
and the OTA combine becomes a `lax.psum` of per-shard partial
superpositions.  These tests pin the contract:

  - every lane's trajectory matches the unsharded engine (rtol 1e-6), on
    1-D ("workers",) and 2-D ("data", "workers") meshes, for pure-FLOA,
    jamming, and mixed-defense grids, composed with chunking/async staging
    and the switch dispatch reference;
  - U % W != 0 ghost-pads the worker axis (clipped batch gather + zeroed
    combine coefficients) without perturbing any real worker;
  - under strict_numerics the engine all-gathers the full slab and replays
    the unsharded reduction order verbatim — bitwise equality;
  - the U=4096 mixed-defense grid runs worker-sharded end to end (psum
    combine + blocked Krum + large-U sort routing in one program).

Multi-device cases need fake host devices; the CI `sweep-sharded` job runs
this module with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

(set before any jax import).  Under plain tier-1 (1 device) they skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    DefenseSpec,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
)
from repro.fl import ExecutionPlan, ScenarioCase, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh
from strategies import regression_batches

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(see the CI sweep-sharded job)")


def worker_problem(u, rounds=3, batch=2, d_in=6, d_h=5):
    """tiny_problem with a configurable worker population (sweep_testlib
    pins U=4; the worker-sharding suite needs non-divisible and large U)."""
    def loss(params, b):
        pred = jax.nn.relu(b["x"] @ params["w1"]) @ params["w2"]
        return jnp.mean((pred - b["y"]) ** 2)

    k = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(k, (d_in, d_h)),
              "w2": jax.random.normal(k, (d_h, 1))}
    dim = d_in * d_h + d_h * 1
    batches = regression_batches(u, rounds, u * batch, d_in)
    return loss, params, dim, batches


def floa_u(u, dim, policy, n_atk, noise=0.05, attack=AttackType.STRONGEST):
    return FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0,
                              noise_std=0.0 if policy == Policy.EF
                              else noise),
        power=PowerConfig(num_workers=u, dim=dim, p_max=1.0, policy=policy),
        attack=AttackConfig(attack=attack if n_atk else AttackType.NONE,
                            byzantine_mask=first_n_mask(u, n_atk)))


def analog_cases(u, dim, num, jam_lane=False):
    """CI/BEV x attacker-count grid at population u, plus an optional
    GAUSSIAN-jamming lane so every RNG stream is exercised."""
    cells = [(pol, n) for n in (0, 1, 2) for pol in (Policy.CI, Policy.BEV)]
    n_grid = num - 1 if jam_lane else num
    cases = [ScenarioCase(f"{cells[i % 6][0].value}@N{cells[i % 6][1]}#{i}",
                          floa_u(u, dim, cells[i % 6][0], cells[i % 6][1]),
                          0.05, seed=100 + i)
             for i in range(n_grid)]
    if jam_lane:
        cases.append(ScenarioCase(
            "jam", floa_u(u, dim, Policy.BEV, max(1, u // 4),
                          attack=AttackType.GAUSSIAN), 0.05, seed=99))
    return cases


def mixed_cases(u, dim, num, lr=0.05):
    """Analog FLOA lanes interleaved with median / trimmed-mean / Krum /
    multi-Krum screening lanes at population u."""
    n_atk = max(1, u // 10)
    defenses = [DefenseSpec(name="median"),
                DefenseSpec(name="trimmed_mean", trim=n_atk),
                DefenseSpec(name="krum", num_byzantine=n_atk),
                DefenseSpec(name="multi_krum", num_byzantine=n_atk, multi=2)]
    period = 2 + len(defenses)
    cases = []
    for i in range(num):
        j = i % period
        if j < 2:
            pol = (Policy.BEV, Policy.CI)[j]
            cases.append(ScenarioCase(f"{pol.value}@#{i}",
                                      floa_u(u, dim, pol, n_atk), lr,
                                      seed=200 + i))
        else:
            spec = defenses[j - 2]
            cases.append(ScenarioCase(
                f"{spec.name}@#{i}",
                floa_u(u, dim, Policy.EF, n_atk, 0.0), lr,
                seed=200 + i, defense=spec))
    return cases


def _eval_fn(p):
    return {"pnorm": sum((x ** 2).sum()
                         for x in jax.tree_util.tree_leaves(p))}


def _assert_lanes_match(sharded, unsharded, rtol=5e-6, atol=1e-6):
    """The psum OTA combine reduces partial superpositions in mesh order
    instead of one big einsum, so float32 trajectories drift ~1e-6/round;
    over a multi-round run we allow a few ulp more than the per-round
    contract.  Exactness is pinned separately by the strict_numerics test."""
    assert sharded.loss.shape == unsharded.loss.shape
    np.testing.assert_allclose(sharded.loss, unsharded.loss,
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(sharded.grad_norm, unsharded.grad_norm,
                               rtol=rtol, atol=atol)
    for k in unsharded.metrics:
        np.testing.assert_allclose(sharded.metrics[k], unsharded.metrics[k],
                                   rtol=rtol, atol=atol)
    for sleaf, uleaf in zip(jax.tree_util.tree_leaves(sharded.params),
                            jax.tree_util.tree_leaves(unsharded.params)):
        assert sleaf.shape == uleaf.shape
        np.testing.assert_allclose(np.asarray(sleaf), np.asarray(uleaf),
                                   rtol=rtol, atol=atol)


@needs_8_devices
@pytest.mark.parametrize("w", [2, 4, 8])
def test_worker_sharded_matches_unsharded_analog(w):
    """Pure-FLOA grid (with a jamming lane): psum OTA combine == einsum
    combine at every worker-shard count, on 2-D ("data", "workers") meshes
    (w < 8) and the 1-D ("workers",) mesh (w == 8)."""
    u = 8
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(analog_cases(u, dim, 6, jam_lane=True))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    mesh = make_sweep_mesh(8, worker_shards=w)
    sh = SweepEngine(loss, spec, eval_fn=_eval_fn,
                     plan=ExecutionPlan(mesh=mesh)).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_worker_sharded_matches_unsharded_mixed_defenses():
    """Mixed analog + screening grid: the digital groups all-gather their
    sub-slab, the analog group psums — every lane matches unsharded."""
    u = 10
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 8))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    mesh = make_sweep_mesh(8, worker_shards=4)   # ("data", "workers") 2x4
    sh = SweepEngine(loss, spec, eval_fn=_eval_fn,
                     plan=ExecutionPlan(mesh=mesh)).run(params, batches)
    _assert_lanes_match(sh, un)


@needs_8_devices
def test_worker_sharded_nondivisible_u_ghost_padding():
    """U % W != 0: ghost workers (clipped batch gather, zeroed combine
    coefficients) must not perturb any real worker's contribution."""
    u = 6                                        # W=4 -> u_loc=2, 2 ghosts
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    eng = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(4, worker_shards=4)))
    assert eng._ws is not None
    assert eng._ws.u_loc == 2 and eng._ws.u_pad == 8
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    _assert_lanes_match(eng.run(params, batches), un)


@needs_8_devices
def test_worker_sharded_strict_numerics_bitwise():
    """strict_numerics + worker sharding: the full slab is all-gathered and
    the unsharded reduction replayed — trajectories are bit-identical."""
    u = 8
    loss, params, dim, batches = worker_problem(u)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        strict_numerics=True)).run(params, batches)
    sh = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, worker_shards=4),
        strict_numerics=True)).run(params, batches)
    np.testing.assert_array_equal(sh.loss, un.loss)
    np.testing.assert_array_equal(sh.grad_norm, un.grad_norm)
    for k in un.metrics:
        np.testing.assert_array_equal(sh.metrics[k], un.metrics[k])
    for sleaf, uleaf in zip(jax.tree_util.tree_leaves(sh.params),
                            jax.tree_util.tree_leaves(un.params)):
        np.testing.assert_array_equal(np.asarray(sleaf), np.asarray(uleaf))


@needs_8_devices
def test_worker_sharded_composes_with_chunking_and_switch():
    """Worker sharding must compose with the other plan knobs: chunked +
    async-staged execution and the per-lane switch dispatch reference both
    reproduce the unsharded trajectories."""
    u = 8
    loss, params, dim, batches = worker_problem(u, rounds=5)
    spec = SweepSpec.build(mixed_cases(u, dim, 6))
    un = SweepEngine(loss, spec, eval_fn=_eval_fn).run(params, batches)
    mesh = make_sweep_mesh(8, worker_shards=2)
    ch = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=mesh, chunk_rounds=2, async_staging=True)).run(params, batches)
    _assert_lanes_match(ch, un)
    sw = SweepEngine(loss, spec, eval_fn=_eval_fn, plan=ExecutionPlan(
        mesh=mesh, grouped_dispatch=False)).run(params, batches)
    _assert_lanes_match(sw, un)


@needs_8_devices
def test_u4096_mixed_defense_end_to_end():
    """The large-U acceptance run: a mixed-defense sweep at U=4096 executes
    worker-sharded end to end — psum OTA combine, blocked Krum (the [U, U]
    distance matrix never materializes), and the large-U sort routing — and
    its analog lanes track the unsharded engine."""
    u = 4096
    loss, params, dim, batches = worker_problem(u, rounds=2, batch=1)
    # lr small enough that 409 STRONGEST attackers don't blow up the CI
    # lane in two rounds; the point is the execution path, not robustness.
    spec = SweepSpec.build(mixed_cases(u, dim, 6, lr=1e-3))
    eng = SweepEngine(loss, spec, plan=ExecutionPlan(
        mesh=make_sweep_mesh(8, worker_shards=8)))
    res = eng.run(params, batches)
    assert res.loss.shape == (6, 2)
    assert np.isfinite(res.loss).all() and np.isfinite(res.grad_norm).all()
    # Spot-check against the unsharded engine (same tolerance contract).
    un = SweepEngine(loss, spec).run(params, batches)
    np.testing.assert_allclose(res.loss, un.loss, rtol=1e-5, atol=1e-4)


def test_worker_plan_validation_runs_everywhere():
    """Tier-1 (single-device) coverage: the plan rejects worker_shards
    without a matching mesh, and a degenerate worker_shards=1 plan is the
    plain engine."""
    u = 4
    loss, params, dim, batches = worker_problem(u, rounds=2)
    spec = SweepSpec.build(analog_cases(u, dim, 3))
    with pytest.raises(ValueError, match="worker_shards"):
        ExecutionPlan(worker_shards=2)
    eng = SweepEngine(loss, spec, plan=ExecutionPlan(
        mesh=make_sweep_mesh(1)))
    assert eng._ws is None and eng.plan.worker_shards == 1
    un = SweepEngine(loss, spec).run(params, batches)
    np.testing.assert_allclose(eng.run(params, batches).loss, un.loss,
                               rtol=1e-6, atol=1e-7)
