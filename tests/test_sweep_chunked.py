"""Scan-of-chunks sweep execution: chunked == monolithic.

`ExecutionPlan(chunk_rounds=C)` splits the one R-round scan into an outer
Python loop over ceil(R/C) inner scans, threading the (state, keys,
absolute-round-offset) carry through the chunk boundaries;
`async_staging=True` additionally double-buffers the per-chunk host->device
batch transfers.  These tests pin the contract: for ANY chunk size —
including C that does not divide R and C > R — the chunked engine replays
the monolithic scan at rtol 1e-6 (bit-for-bit under `strict_numerics`), on
both state paths, with grouped defense dispatch, with caller-provided keys,
and under a ("data",) mesh.

Multi-device cases need fake host devices; the CI `sweep-sharded` job runs
this module with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

(set before any jax import).  Under plain tier-1 (1 device) those cases
skip and the single-device-mesh + unsharded cases run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.data import FederatedSampler, iter_chunk_blocks
from repro.fl import ExecutionPlan, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh
import sweep_testlib as LIB
from strategies import toy_shards

U = LIB.U

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(see the CI sweep-sharded job)")


def _tiny_problem(rounds=7, **kw):
    return LIB.tiny_problem(rounds=rounds, **kw)


def _grid_cases(dim, num):
    # jam_lane: noise + jamming lanes, so every RNG stream crosses chunk
    # boundaries.
    return LIB.grid_cases(dim, num, jam_lane=True)


def _defense_grid_cases(dim, num):
    # One family per screening mechanism (sort, masked trim, pairwise
    # distances, Weiszfeld) keeps the chunk-boundary coverage while tracing
    # fewer groups than the sharded suite's full list.
    return LIB.defense_grid_cases(dim, num, defenses=(
        LIB.DEFENSES[1], LIB.DEFENSES[2], LIB.DEFENSES[3], LIB.DEFENSES[5]))


def _assert_results_match(a, b, bitwise=False):
    close = (np.testing.assert_array_equal if bitwise else
             lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-6,
                                                     atol=1e-7))
    assert a.loss.shape == b.loss.shape
    close(a.loss, b.loss)
    close(a.grad_norm, b.grad_norm)
    assert set(a.metrics) == set(b.metrics)
    for k in b.metrics:
        close(np.asarray(a.metrics[k]), np.asarray(b.metrics[k]))
    for aleaf, bleaf in zip(jax.tree_util.tree_leaves(a.params),
                            jax.tree_util.tree_leaves(b.params)):
        assert aleaf.shape == bleaf.shape
        close(np.asarray(aleaf), np.asarray(bleaf))


# ------------------------------------------------------------ data utility


def test_iter_chunk_blocks_partitions_exactly():
    """ceil(R/C) blocks, last one short, concat == input, numpy views."""
    batches = {"x": np.arange(7 * 3).reshape(7, 3), "y": np.arange(7.0)}
    blocks = list(iter_chunk_blocks(batches, 3))
    assert [b["x"].shape[0] for b in blocks] == [3, 3, 1]
    for k in batches:
        np.testing.assert_array_equal(
            np.concatenate([b[k] for b in blocks]), batches[k])
        assert np.shares_memory(blocks[0][k], batches[k])  # zero-copy view
    (only,) = iter_chunk_blocks(batches, 99)
    assert only["x"].shape[0] == 7
    with pytest.raises(ValueError):
        next(iter_chunk_blocks(batches, 0))


def test_iter_round_chunks_replays_stack_rounds():
    """FederatedSampler.iter_round_chunks draws the same stream as one big
    stack_rounds call (the chunked engine's incremental host pipeline)."""
    shards = toy_shards(0, U)
    stacked = FederatedSampler(shards, batch_per_worker=4, seed=7).stack_rounds(7)
    blocks = list(FederatedSampler(shards, batch_per_worker=4,
                                   seed=7).iter_round_chunks(7, 3))
    assert [b["x"].shape[0] for b in blocks] == [3, 3, 1]
    for k in stacked:
        np.testing.assert_array_equal(
            np.concatenate([b[k] for b in blocks]), stacked[k])


# ------------------------------------------------- chunked == monolithic


@pytest.mark.parametrize("chunk", [1, 3, 7, 10])
def test_chunked_matches_monolithic_flat(chunk):
    """Flat-state path, R=7 rounds: every chunk size — divisible, not
    divisible (the short-remainder recompile), C == R, C > R — replays the
    monolithic scan, metrics schedule included."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 6))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    mono = SweepEngine(loss, spec, eval_fn=eval_fn, eval_every=2).run(
        params, batches)
    ch = SweepEngine(
        loss, spec, eval_fn=eval_fn, eval_every=2,
        plan=ExecutionPlan(chunk_rounds=chunk)).run(params, batches)
    _assert_results_match(ch, mono)


def test_chunked_matches_monolithic_tree_state():
    """Tree-state path: the chunk carry is the stacked params pytree."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 5))
    mono = SweepEngine(
        loss, spec, plan=ExecutionPlan(flat_state=False)).run(params, batches)
    ch = SweepEngine(
        loss, spec, plan=ExecutionPlan(flat_state=False, chunk_rounds=3)).run(
        params, batches)
    _assert_results_match(ch, mono)


@pytest.mark.parametrize("flat_state", [True, False])
def test_chunked_strict_numerics_bitwise(flat_state):
    """Acceptance: under strict_numerics the chunked engine is BIT-identical
    to the monolithic scan on both state paths (R % C != 0)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 5))
    mono = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            flat_state=flat_state, strict_numerics=True)).run(params, batches)
    ch = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            flat_state=flat_state, strict_numerics=True,
            chunk_rounds=3)).run(params, batches)
    _assert_results_match(ch, mono, bitwise=True)


def test_chunked_rng_continuity_with_custom_keys():
    """Caller-provided per-lane keys: the carried key state crossing a chunk
    boundary must continue the monolithic split sequence (noise + jamming
    lanes make every round consume draws)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 4))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4) + 42)
    mono = SweepEngine(
        loss, spec, plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches, keys=keys)
    ch = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(strict_numerics=True, chunk_rounds=2)).run(
        params, batches, keys=keys)
    _assert_results_match(ch, mono, bitwise=True)


def test_async_staging_bit_identical_to_sync():
    """async_staging is a pure scheduling change: identical programs and
    operands, so bit-identical results."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 4))
    sync = SweepEngine(
        loss, spec, plan=ExecutionPlan(chunk_rounds=3)).run(params, batches)
    asy = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            chunk_rounds=3, async_staging=True)).run(params, batches)
    _assert_results_match(asy, sync, bitwise=True)


def test_chunked_grouped_dispatch_mixed_grid():
    """Mixed analog+defense grid under the default grouped dispatch: the lane
    permutation and host-side scatter-back must survive the chunk split (and
    match the switch-dispatch reference)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_defense_grid_cases(dim, 8))
    mono = SweepEngine(loss, spec).run(params, batches)
    ch = SweepEngine(
        loss, spec, plan=ExecutionPlan(chunk_rounds=3)).run(params, batches)
    _assert_results_match(ch, mono)
    switch = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            grouped_dispatch=False, chunk_rounds=3)).run(params, batches)
    _assert_results_match(switch, mono)


def test_chunked_eval_schedule_anchored_to_absolute_round():
    """eval_every=3 with C=2: due rounds {0, 3, 6} straddle chunk boundaries;
    the NaN on/off pattern must match the monolithic schedule exactly."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 3))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    ch = SweepEngine(
        loss, spec, eval_fn=eval_fn, eval_every=3,
        plan=ExecutionPlan(chunk_rounds=2)).run(params, batches)
    acc = np.asarray(ch.metrics["accuracy"])
    due = [0, 3, 6]  # t % 3 == 0 plus the final round (6 == R-1 here)
    assert not np.isnan(acc[:, due]).any()
    off = [t for t in range(acc.shape[1]) if t not in due]
    assert np.isnan(acc[:, off]).all()


def test_chunked_zero_rounds_matches_monolithic():
    """Degenerate R=0 stack: the chunked engine must fall back to the
    monolithic program's empty [S, 0] outputs instead of crashing."""
    loss, params, dim, batches = _tiny_problem()
    batches = {k: v[:0] for k, v in batches.items()}
    spec = SweepSpec.build(_grid_cases(dim, 2))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    mono = SweepEngine(loss, spec, eval_fn=eval_fn).run(params, batches)
    ch = SweepEngine(
        loss, spec, eval_fn=eval_fn, plan=ExecutionPlan(chunk_rounds=3)).run(
        params, batches)
    assert ch.loss.shape == mono.loss.shape == (2, 0)
    for cleaf, mleaf in zip(jax.tree_util.tree_leaves(ch.params),
                            jax.tree_util.tree_leaves(mono.params)):
        np.testing.assert_array_equal(np.asarray(cleaf), np.asarray(mleaf))


def test_chunk_knob_validation():
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 2))
    with pytest.raises(ValueError):
        SweepEngine(loss, spec, plan=ExecutionPlan(chunk_rounds=0))
    with pytest.raises(ValueError):
        SweepEngine(
            loss, spec,
            plan=ExecutionPlan(async_staging=True))  # needs chunk_rounds


# ------------------------------------------------------------------- mesh


def test_single_device_mesh_chunked_matches_unsharded_monolithic():
    """Degenerate 1-device mesh + chunking + async staging == the plain
    monolithic engine.  Runs everywhere (tier-1)."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 6))
    eval_fn = lambda p: {"accuracy": jnp.mean(p["w1"]) * 0 + 0.5}
    mono = SweepEngine(loss, spec, eval_fn=eval_fn).run(params, batches)
    ch = SweepEngine(
        loss, spec, eval_fn=eval_fn, plan=ExecutionPlan(
            mesh=make_sweep_mesh(1), chunk_rounds=3,
            async_staging=True)).run(params, batches)
    _assert_results_match(ch, mono)


@needs_8_devices
def test_sharded_chunked_matches_unsharded_monolithic():
    """8 fake devices, S=13 (ghost-padded), C=3 over R=7: sharding and
    chunking compose; every real lane replays the unsharded monolithic
    engine."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_grid_cases(dim, 13))
    mono = SweepEngine(loss, spec).run(params, batches)
    ch = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            mesh=make_sweep_mesh(8), chunk_rounds=3,
            async_staging=True)).run(params, batches)
    assert ch.loss.shape[0] == 13  # ghosts dropped
    _assert_results_match(ch, mono)


@needs_8_devices
def test_sharded_chunked_grouped_defense_grid():
    """Acceptance: grouped dispatch + 8 fake devices + chunking on the mixed
    defense grid (per-group ghost padding), rtol 1e-6 vs the unsharded
    monolithic engine and bitwise vs the sharded monolithic engine under
    strict_numerics."""
    loss, params, dim, batches = _tiny_problem()
    spec = SweepSpec.build(_defense_grid_cases(dim, 13))
    mono = SweepEngine(loss, spec).run(params, batches)
    eng = SweepEngine(
        loss, spec,
        plan=ExecutionPlan(mesh=make_sweep_mesh(8), chunk_rounds=3))
    assert eng._groups is not None and eng._groups.exec_lanes % 8 == 0
    ch = eng.run(params, batches)
    assert ch.loss.shape[0] == 13
    _assert_results_match(ch, mono)

    sh_mono = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            mesh=make_sweep_mesh(8),
            strict_numerics=True)).run(params, batches)
    sh_ch = SweepEngine(
        loss, spec, plan=ExecutionPlan(
            mesh=make_sweep_mesh(8), strict_numerics=True, chunk_rounds=2,
            async_staging=True)).run(params, batches)
    _assert_results_match(sh_ch, sh_mono, bitwise=True)
