"""Theory (Thms 1-3, Remarks) — closed forms + hypothesis property tests."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e '.[test]'; CI's tier-1 job has it)")
from hypothesis import given, settings, strategies as st

from repro.core import theory as TH
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    aggregate, first_n_mask, per_worker_grads,
)


def test_remark2_ci_threshold_iso():
    """The paper's Remark-2 bound is sufficient; the exact iso threshold
    (solving omega_CI>0 from eq. 21) is U/(1+sqrt(pi U)/2)."""
    for u in (6, 10, 20, 50):
        paper_thr = TH.max_attackers_ci_iso(u)
        exact_thr = TH.max_attackers_ci_iso_exact(u)
        assert paper_thr <= exact_thr  # paper bound is conservative
        for n in range(0, u // 2 + 1):
            tp = TH.TheoryParams(num_workers=u, num_attackers=n, dim=100)
            if n < paper_thr:
                assert TH.omega_ci(tp) > 0, (u, n)     # sufficient
            if n < exact_thr:
                assert TH.omega_ci(tp) > 0, (u, n)     # exact, below
            if n > exact_thr:
                assert TH.omega_ci(tp) < 0, (u, n)     # exact, above


def test_remark4_bev_threshold_iso():
    for u in (6, 10, 20):
        for n in range(0, u + 1):
            tp = TH.TheoryParams(num_workers=u, num_attackers=n, dim=100)
            if n < u / 2:
                assert TH.omega_bev(tp) > 0
            if n > u / 2:
                assert TH.omega_bev(tp) < 0


def test_bev_tolerates_more_attackers_than_ci():
    for u in (6, 10, 24, 100):
        assert TH.max_attackers_bev_iso(u) >= TH.max_attackers_ci_iso(u)


def test_omega_formulas_match_paper_special_case():
    # Remark 2: omega_CI = (M/sqrt(U) - sqrt(N^2 pi/4)) sqrt(2 pmax sigma^2 / D)
    u, n, d = 10, 3, 50
    tp = TH.TheoryParams(num_workers=u, num_attackers=n, dim=d)
    m = u - n
    want = (m / math.sqrt(u) - math.sqrt(n**2 * math.pi / 4.0)) * math.sqrt(
        2.0 * 1.0 * 1.0 / d)
    assert np.isclose(TH.omega_ci(tp), want, rtol=1e-12)


def test_lemma1_no_attack_ci():
    # N=0: omega_CI^2 == Omega_CI (so the rate collapses to the EF form)
    tp = TH.TheoryParams(num_workers=10, num_attackers=0, dim=50)
    assert np.isclose(TH.omega_ci(tp) ** 2, TH.Omega_ci(tp), rtol=1e-12)


def test_remark6_bev_no_attack_slower():
    # omega_BEV^2 <= Omega_BEV at N=0 (BEV pays a benign-case penalty)
    tp = TH.TheoryParams(num_workers=10, num_attackers=0, dim=50)
    assert TH.omega_bev(tp) ** 2 <= TH.Omega_bev(tp) + 1e-12


@given(
    u=st.integers(4, 32),
    frac=st.floats(0.0, 0.45),
    sigma=st.floats(0.2, 3.0),
    pmax=st.floats(0.1, 4.0),
    d=st.integers(10, 10_000),
)
@settings(max_examples=60, deadline=None)
def test_property_convergence_condition_consistent(u, frac, sigma, pmax, d):
    """alpha < 2 omega/(L Omega) <=> converges() for both policies."""
    n = int(u * frac)
    tp = TH.TheoryParams(num_workers=u, num_attackers=n, dim=d,
                         sigma=sigma, p_max=pmax)
    lip = 1.7
    for pol in ("ci", "bev"):
        bound = TH.lr_upper_bound(tp, pol, lip)
        if bound > 0:
            assert TH.converges(tp, pol, bound * 0.5, lip)
            assert not TH.converges(tp, pol, bound * 1.5, lip)
        else:
            assert not TH.converges(tp, pol, 1e-3, lip)


@given(st.integers(4, 24), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_property_omega_monotone_in_attackers(u, n):
    """More attackers never helps: omega decreases monotonically with N."""
    n = min(n, u - 1)
    for pol_omega in (TH.omega_ci, TH.omega_bev):
        prev = None
        for k in range(n + 1):
            tp = TH.TheoryParams(num_workers=u, num_attackers=k, dim=64)
            w = pol_omega(tp)
            if prev is not None:
                assert w <= prev + 1e-12
            prev = w


def test_rate_bound_decreases_with_T():
    tp = TH.TheoryParams(num_workers=10, num_attackers=1, dim=50890)
    kw = dict(lipschitz=1.0, f0_minus_fstar=2.0, delta2=1.0, eps_bound=1.0,
              noise_std=0.01, alpha_bar=0.5)
    b1 = TH.rate_bound(tp, "bev", total_steps=100, **kw)
    b2 = TH.rate_bound(tp, "bev", total_steps=10_000, **kw)
    assert b2 < b1
    assert TH.rate_bound(
        TH.TheoryParams(num_workers=10, num_attackers=6, dim=50890),
        "bev", total_steps=100, **kw) == float("inf")


def test_thm1_strongest_attack_is_worst_direction():
    """Thm 1 (empirical form): among attacker payload choices with the same
    power accounting, the sign-flipped own gradient minimizes the expected
    inner product g_t . contribution — i.e. maximally deters descent."""
    key = jax.random.PRNGKey(0)
    u, d = 8, 32
    g = jax.random.normal(key, (u, d)) * 0.7 + 0.5  # correlated worker grads
    g_mean = g.mean(0)
    gbar = float(g.mean())
    eps2 = float(g.var())
    phat = 1.0 / math.sqrt(d * (gbar**2 + eps2))
    # candidate payloads for attacker 0, all obeying the same accounting
    rng = np.random.default_rng(1)
    best = None
    for trial in range(200):
        v = rng.normal(size=d)
        v = v / np.sqrt((v**2).mean()) * np.sqrt(eps2 + gbar**2)  # same power
        score = float(np.dot(np.asarray(g_mean), v))
        best = score if best is None else min(best, score)
    flip = -np.asarray(g[0]) * 1.0
    flip_score = float(np.dot(np.asarray(g_mean), flip))
    # sign-flip of one's own (correlated) gradient beats the best of 200
    # random same-power directions (deterministic seeds)
    assert flip_score < 0
    assert flip_score <= best
    # and it is strictly worse than honest behaviour
    honest_score = float(np.dot(np.asarray(g_mean), np.asarray(g[0])))
    assert flip_score < honest_score
