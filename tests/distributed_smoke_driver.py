"""One process of the 2-process `jax.distributed` CPU smoke
(tests/test_distributed_bootstrap.py): bootstrap the distributed runtime
via `initialize_distributed` (gloo CPU collectives), build the
process-spanning ("data",) sweep mesh with the unchanged
`make_sweep_mesh`, run a tiny sharded sweep — per-process staging through
`put_with_sharding` / `stage_batch_block` — and check it against the
process-local unsharded engine.  With a checkpoint dir (shared by both
processes) it also exercises the multi-process checkpoint/resume edge:
the collective fetch in `_save_checkpoint` runs on BOTH ranks (only the
write is rank 0's), and `resume=True` broadcasts rank 0's latest step so
both ranks continue from the same boundary, bitwise-equal to the
uninterrupted sharded run.

Usage: distributed_smoke_driver.py <port> <rank> [ckpt_dir] (always 2
processes; launch with XLA_FLAGS=--xla_force_host_platform_device_count=1
so each process owns exactly one CPU device and the mesh genuinely spans
both).
"""
import sys


def main() -> None:
    port, rank = sys.argv[1], int(sys.argv[2])
    ckpt_dir = sys.argv[3] if len(sys.argv) > 3 else None

    import jax

    jax.config.update("jax_threefry_partitionable", True)

    import numpy as np

    from repro import ExecutionPlan, initialize_distributed, make_sweep_mesh
    from repro.fl import SweepEngine, SweepSpec
    from sweep_testlib import grid_cases, tiny_problem

    assert initialize_distributed(f"127.0.0.1:{port}", num_processes=2,
                                  process_id=rank)
    assert jax.process_count() == 2
    assert jax.local_device_count() == 1
    assert len(jax.devices()) == 2, "jax.devices() must be global after init"

    loss, params, dim, batches = tiny_problem(rounds=4)
    spec = SweepSpec.build(grid_cases(dim, num=4))
    mesh = make_sweep_mesh()          # spans both processes, no new API
    assert mesh.axis_names == ("data",) and not set(
        mesh.devices.flat) <= set(jax.local_devices())

    plan = ExecutionPlan(mesh=mesh, chunk_rounds=2, checkpoint_dir=ckpt_dir)
    sharded = SweepEngine(loss, spec, plan=plan).run(params, batches)
    local = SweepEngine(loss, spec).run(params, batches)
    np.testing.assert_allclose(np.asarray(sharded.loss),
                               np.asarray(local.loss),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(sharded.grad_norm),
                               np.asarray(local.grad_norm),
                               rtol=1e-6, atol=1e-7)
    if ckpt_dir is not None:
        # The run above committed the round-2 boundary (collective fetch on
        # both ranks, rank-0 write); resuming off it must reproduce the
        # uninterrupted sharded run bit-for-bit on both ranks.
        from repro import latest_step
        assert latest_step(ckpt_dir) == 2, latest_step(ckpt_dir)
        resumed = SweepEngine(loss, spec, plan=plan).run(
            params, batches, resume=True)
        np.testing.assert_array_equal(np.asarray(sharded.loss),
                                      np.asarray(resumed.loss))
        np.testing.assert_array_equal(np.asarray(sharded.grad_norm),
                                      np.asarray(resumed.grad_norm))
    print(f"DISTRIBUTED_SMOKE_OK rank={rank}")


if __name__ == "__main__":
    main()
