"""Shared data generators and hypothesis strategies for the test suites.

One module owns the random-input recipes the property and equivalence suites
previously duplicated: the [U, D] worker-gradient matrix, the gradient
pytree, the stacked regression batch stream, and the toy federated shard
dict.  Keeping them here means a change to the input distribution (scale,
dtype, layout) lands in every suite at once — and the hypothesis suites draw
their integer/float axes from the same named strategies, so the search-space
bounds are defined exactly once.

The deterministic generators need only numpy/jax.  The strategy factories
need hypothesis, which tier-1 may not have installed — callers must
`pytest.importorskip("hypothesis")` (see HYPOTHESIS_REASON) before touching
them; importing THIS module stays safe either way.
"""
import jax
import numpy as np

HYPOTHESIS_REASON = ("hypothesis not installed (pip install -e '.[test]'; "
                     "CI's tier-1 job has it)")

try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 without the test extra
    st = None
    HAVE_HYPOTHESIS = False


# ------------------------------------------------- deterministic generators

def flat_grads(seed: int, u: int, d: int) -> np.ndarray:
    """[U, D] float32 worker-gradient matrix, mildly off-center — the input
    the defense-kernel property suite screens."""
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(u, d)) * 0.7 + 0.1).astype(np.float32)


def worker_grad_tree(key, u: int, d: int):
    """One-leaf gradient pytree with a leading worker axis ([U, D])."""
    g = jax.random.normal(key, (u, d)) * 0.5 + 0.1
    return {"w": g}


def regression_batches(seed: int, rounds: int, rows: int,
                       d_in: int) -> dict:
    """Stacked [R, rows, d_in] / [R, rows, 1] regression batches — the batch
    stream every tiny-MLP sweep problem consumes (rows = U * batch)."""
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(size=(rounds, rows, d_in)).astype(np.float32),
            "y": rng.normal(size=(rounds, rows, 1)).astype(np.float32)}


def toy_shards(seed: int, u: int, n: int = 20, d: int = 3,
               classes: int = 4) -> dict:
    """{worker: (x [n, d], y [n])} shard dict for FederatedSampler tests."""
    rng = np.random.default_rng(seed)
    return {i: (rng.normal(size=(n, d)).astype(np.float32),
                rng.integers(0, classes, size=n)) for i in range(u)}


# ------------------------------------------------------ hypothesis strategies

def _needs_hypothesis():
    if not HAVE_HYPOTHESIS:
        raise RuntimeError(
            "hypothesis strategies requested without hypothesis installed; "
            "pytest.importorskip('hypothesis') first — " + HYPOTHESIS_REASON)


def worker_counts(lo: int = 3, hi: int = 10):
    """Number of workers U (most kernels need U >= 3)."""
    _needs_hypothesis()
    return st.integers(lo, hi)


def dims(lo: int = 2, hi: int = 64):
    """Gradient dimension D."""
    _needs_hypothesis()
    return st.integers(lo, hi)


def seeds(hi: int = 10**6):
    """PRNG seeds for the deterministic generators above."""
    _needs_hypothesis()
    return st.integers(0, hi)


def byz_counts(hi: int = 4, lo: int = 0):
    """Byzantine cohort sizes (callers clamp to their U-dependent bound)."""
    _needs_hypothesis()
    return st.integers(lo, hi)


def shifts(bound: float = 5.0):
    """Translation offsets for equivariance properties."""
    _needs_hypothesis()
    return st.floats(-bound, bound)


def attack_scales(lo: float = 1e2, hi: float = 1e6):
    """Magnitudes of Byzantine junk rows for breakdown-point properties."""
    _needs_hypothesis()
    return st.floats(lo, hi)
