"""Unit tests for the core FLOA library (channel, power, attacks, eq. 7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    aggregate, first_n_mask, floa_grad, mean_aggregate, noise_std_for_snr,
    per_worker_grads, sample_channel_gains,
)
from repro.core import attacks as ATK
from repro.core import power_control as PC
from repro.core import standardize as S

U, D = 8, 64


def make_cfg(policy=Policy.BEV, n_atk=0, noise=0.0,
             attack=AttackType.STRONGEST, sigma=1.0):
    return FLOAConfig(
        channel=ChannelConfig(num_workers=U, sigma=sigma, noise_std=noise),
        power=PowerConfig(num_workers=U, dim=D, p_max=1.0, policy=policy),
        attack=AttackConfig(attack=attack if n_atk else AttackType.NONE,
                            byzantine_mask=first_n_mask(U, n_atk)),
    )


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_problem(key):
    kx, ky, kw = jax.random.split(key, 3)
    params = {"w": jax.random.normal(kw, (4, 1)) * 0.3}
    batch = {"x": jax.random.normal(kx, (U * 4, 4)),
             "y": jax.random.normal(ky, (U * 4, 1))}
    return params, batch


def test_channel_moments():
    cfg = ChannelConfig(num_workers=2000, sigma=1.5)
    h = sample_channel_gains(jax.random.PRNGKey(0), cfg)
    # E|h| = sigma sqrt(pi/2); E|h|^2 = 2 sigma^2
    assert np.isclose(float(jnp.mean(h)), 1.5 * np.sqrt(np.pi / 2), rtol=0.05)
    assert np.isclose(float(jnp.mean(h**2)), 2 * 1.5**2, rtol=0.07)


def test_ci_inverts_channel():
    ch = ChannelConfig(num_workers=U, sigma=1.0)
    pw = PowerConfig(num_workers=U, dim=D, p_max=1.0, policy=Policy.CI)
    h = sample_channel_gains(jax.random.PRNGKey(1), ch)
    coeff = PC.received_coefficients(h, pw, ch)
    # all received amplitudes identical == b0
    b0 = PC.ci_b0(pw, ch)
    np.testing.assert_allclose(np.asarray(coeff), float(b0), rtol=1e-6)


def test_bev_max_power():
    ch = ChannelConfig(num_workers=U, sigma=1.0)
    pw = PowerConfig(num_workers=U, dim=D, p_max=2.0, policy=Policy.BEV)
    h = sample_channel_gains(jax.random.PRNGKey(1), ch)
    amp = PC.transmit_amplitudes(h, pw, ch)
    np.testing.assert_allclose(np.asarray(amp), np.sqrt(2.0 / D), rtol=1e-6)
    # power constraint (eq. 4): D p^2 <= p_max
    assert np.all(D * np.asarray(amp) ** 2 <= 2.0 + 1e-6)


def test_truncated_ci_respects_power_constraint():
    ch = ChannelConfig(num_workers=U, sigma=1.0)
    pw = PowerConfig(num_workers=U, dim=D, p_max=1.0, policy=Policy.TRUNCATED_CI)
    for i in range(20):
        h = sample_channel_gains(jax.random.PRNGKey(i), ch)
        amp = PC.transmit_amplitudes(h, pw, ch)
        assert np.all(D * np.asarray(amp) ** 2 <= 1.0 + 1e-6)


def test_standardize_roundtrip():
    g = jax.random.normal(jax.random.PRNGKey(0), (U, D))
    tree = {"a": g[:, :32], "b": g[:, 32:]}
    gbar_i, eps2_i = S.per_worker_scalar_stats(tree)
    np.testing.assert_allclose(np.asarray(gbar_i), np.asarray(g).mean(1),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(eps2_i), np.asarray(g).var(1),
                               rtol=1e-4)
    gbar, eps2 = S.global_stats(gbar_i, eps2_i)
    std = S.standardize(tree, gbar, eps2)
    back = S.destandardize(std, jnp.float32(1.0), gbar, eps2)
    # coeff_sum=1 and a single worker's view: destandardize(standardize(g)) = g
    np.testing.assert_allclose(
        np.asarray(back["a"]), np.asarray(tree["a"]), rtol=2e-4, atol=2e-5)


def test_strongest_attack_power_accounting():
    # eq. 32: E||phat ghat||^2 = phat^2 D (eps2 + gbar^2) == p_max
    gbar, eps2 = jnp.float32(0.3), jnp.float32(0.7)
    phat = ATK.strongest_attack_amplitude(jnp.float32(1.0), D, gbar, eps2)
    np.testing.assert_allclose(
        float(phat**2 * D * (eps2 + gbar**2)), 1.0, rtol=1e-6)


def test_aggregate_matches_manual_eq7():
    """The aggregate must equal eq. (7) computed by hand in numpy."""
    key = jax.random.PRNGKey(3)
    params, batch = make_problem(key)
    cfg = make_cfg(policy=Policy.BEV, n_atk=2, noise=0.0)
    grads_u, _ = per_worker_grads(quad_loss, params, batch, U)
    gagg, aux = aggregate(grads_u, key, cfg)

    g = np.asarray(grads_u["w"]).reshape(U, -1)
    gbar_i, eps2_i = g.mean(1), g.var(1)
    gbar, eps2 = gbar_i.mean(), eps2_i.mean()
    h = np.asarray(aux["h_abs"])
    s_honest = np.sqrt(1.0 / D) * h
    phat = np.sqrt(1.0 / (D * (gbar**2 + eps2)))
    want = np.zeros(g.shape[1])
    for i in range(U):
        if i < 2:  # attacker: -eps*phat*h*g + p|h|*gbar*1
            want += -np.sqrt(eps2) * phat * h[i] * g[i]
            want += s_honest[i] * gbar
        else:
            want += s_honest[i] * g[i]
    np.testing.assert_allclose(np.asarray(gagg["w"]).reshape(-1), want,
                               rtol=2e-4, atol=1e-6)


def test_ef_equals_mean():
    key = jax.random.PRNGKey(4)
    params, batch = make_problem(key)
    grads_u, _ = per_worker_grads(quad_loss, params, batch, U)
    gagg, _ = aggregate(grads_u, key, make_cfg(policy=Policy.EF))
    want = mean_aggregate(grads_u)
    np.testing.assert_allclose(np.asarray(gagg["w"]), np.asarray(want["w"]),
                               rtol=1e-5)


def test_per_worker_grads_match_individual():
    key = jax.random.PRNGKey(5)
    params, batch = make_problem(key)
    grads_u, _ = per_worker_grads(quad_loss, params, batch, U)
    for i in [0, 3, U - 1]:
        sub = {k: v[i * 4:(i + 1) * 4] for k, v in batch.items()}
        gi = jax.grad(quad_loss)(params, sub)
        np.testing.assert_allclose(np.asarray(grads_u["w"][i]),
                                   np.asarray(gi["w"]), rtol=1e-5)


def test_noise_snr_relation():
    z = noise_std_for_snr(1.0, D, 10.0)
    assert np.isclose(1.0 / (D * z**2), 10.0, rtol=1e-6)


def test_gaussian_attack_adds_noise_only():
    key = jax.random.PRNGKey(6)
    params, batch = make_problem(key)
    cfg = make_cfg(policy=Policy.BEV, n_atk=2, attack=AttackType.GAUSSIAN)
    grads_u, _ = per_worker_grads(quad_loss, params, batch, U)
    gagg, aux = aggregate(grads_u, key, cfg)
    # attacker payload coefficients are zero
    assert np.allclose(np.asarray(aux["coeffs"][:2]), 0.0)
    assert np.all(np.asarray(aux["coeffs"][2:]) > 0.0)
