"""Adaptive-adversary scenario axes: degenerate and composition contracts.

PR 8 adds four branchless lane axes to the compiled sweep — COLLUDING /
OMNISCIENT directional attacks, Gauss-Markov fading (chan_rho), and K-of-U
per-round participation — all inside the ONE jitted scan.  These tests pin
the contracts that make the axes safe to mix into existing grids:

* markov rho=0 lanes are BITWISE identical to the i.i.d. channel draw, even
  when they share a sweep with rho>0 lanes (the legacy key stream is
  untouched: slot 0 still draws the i.i.d. gains, the Markov innovation
  comes from fold_in side-channels);
* participants=U runs the full masked machinery and is BITWISE identical to
  participants=None (the masked-mean scale is exactly 1.0 at a full mask);
* a cohort-of-1 OMNISCIENT attacker on identical worker shards reproduces
  the STRONGEST attack (eq. 18) to float tolerance — the honest mean IS the
  negated common gradient;
* with every axis active the engine's own equivalence matrix still holds
  bitwise under strict_numerics: flat == tree state, grouped == switch
  dispatch, chunked == monolithic, sharded == unsharded (8 fake devices via
  the CI sweep-sharded job; single-device mesh runs everywhere).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_threefry_partitionable", True)

from repro.core.attacks import AttackType
from repro.core.channel import ChannelConfig
from repro.core.power_control import Policy
from repro.core.scenario import DefenseSpec
from repro.fl import ExecutionPlan, ScenarioCase, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh
from sweep_testlib import U, floa as _floa, grid_cases, tiny_problem

needs_8_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(see the CI sweep-sharded job)")


def _with_rho(cfg, rho):
    """FLOAConfig with the channel's markov_rho replaced."""
    return dataclasses.replace(
        cfg, channel=dataclasses.replace(cfg.channel, markov_rho=rho))


def _axes_grid(dim):
    """Mixed grid exercising every new axis at once: legacy lanes, a Markov
    lane, colluding/omniscient lanes, partial participation (analog and
    digital), and their compositions."""
    return [
        ScenarioCase("legacy-bev", _floa(dim, Policy.BEV, 2), 0.05, seed=300),
        ScenarioCase("legacy-ci", _floa(dim, Policy.CI, 1), 0.05, seed=301),
        ScenarioCase("markov", _with_rho(_floa(dim, Policy.BEV, 1), 0.9),
                     0.05, seed=302),
        ScenarioCase("collude",
                     _floa(dim, Policy.CI, 2, attack=AttackType.COLLUDING),
                     0.05, seed=303),
        ScenarioCase("omni",
                     _floa(dim, Policy.BEV, 1, attack=AttackType.OMNISCIENT),
                     0.05, seed=304),
        ScenarioCase("part3", _floa(dim, Policy.BEV, 1), 0.05, seed=305,
                     participants=3),
        ScenarioCase("markov+collude+part",
                     _with_rho(_floa(dim, Policy.CI, 2,
                                     attack=AttackType.COLLUDING), 0.5),
                     0.05, seed=306, participants=3),
        ScenarioCase("median-part", _floa(dim, Policy.EF, 1, 0.0), 0.05,
                     seed=307, defense=DefenseSpec(name="median"),
                     participants=3),
        ScenarioCase("trimmed-part", _floa(dim, Policy.EF, 2, 0.0), 0.05,
                     seed=308, defense=DefenseSpec(name="trimmed_mean",
                                                   trim=1),
                     participants=3),
    ]


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.loss), np.asarray(b.loss))
    np.testing.assert_array_equal(np.asarray(a.grad_norm),
                                  np.asarray(b.grad_norm))
    for la, lb in zip(jax.tree_util.tree_leaves(a.params),
                      jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_close(a, b):
    np.testing.assert_allclose(a.loss, b.loss, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(a.grad_norm, b.grad_norm, rtol=1e-6,
                               atol=1e-7)
    for la, lb in zip(jax.tree_util.tree_leaves(a.params),
                      jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------- markov

def test_markov_rho0_lanes_bitwise_equal_iid():
    """Legacy lanes inside a sweep that ALSO carries a rho>0 Markov lane are
    bitwise unchanged: activating the fading carry must not perturb anyone
    else's key stream or arithmetic."""
    loss, params, dim, batches = tiny_problem()
    legacy = grid_cases(dim, 2)
    ref = SweepEngine(loss, SweepSpec.build(legacy)).run(params, batches)
    mixed = legacy + [ScenarioCase(
        "markov", _with_rho(_floa(dim, Policy.BEV, 1), 0.9), 0.05, seed=999)]
    got = SweepEngine(loss, SweepSpec.build(mixed)).run(params, batches)
    np.testing.assert_array_equal(np.asarray(got.loss[:2]),
                                  np.asarray(ref.loss))
    np.testing.assert_array_equal(np.asarray(got.grad_norm[:2]),
                                  np.asarray(ref.grad_norm))
    assert np.all(np.isfinite(np.asarray(got.loss[2])))


def test_markov_rho0_lane_bitwise_equal_explicit():
    """A lane explicitly constructed with markov_rho=0.0 == the same lane
    without the field — rho=0 degenerates to the i.i.d. draw bitwise."""
    loss, params, dim, batches = tiny_problem()
    base = grid_cases(dim, 3)
    zeroed = [dataclasses.replace(c, floa=_with_rho(c.floa, 0.0))
              for c in base]
    a = SweepEngine(loss, SweepSpec.build(base)).run(params, batches)
    b = SweepEngine(loss, SweepSpec.build(zeroed)).run(params, batches)
    _assert_bitwise(a, b)


def test_markov_lane_differs_from_iid():
    """rho=0.9 genuinely changes the channel realization (same seed)."""
    loss, params, dim, batches = tiny_problem()
    iid = ScenarioCase("l", _floa(dim, Policy.BEV, 1), 0.05, seed=42)
    mk = ScenarioCase("l", _with_rho(_floa(dim, Policy.BEV, 1), 0.9),
                      0.05, seed=42)
    a = SweepEngine(loss, SweepSpec.build([iid])).run(params, batches)
    b = SweepEngine(loss, SweepSpec.build([mk])).run(params, batches)
    assert not np.allclose(a.loss, b.loss)
    assert np.all(np.isfinite(np.asarray(b.loss)))


def test_markov_rho_validation():
    with pytest.raises(ValueError):
        ChannelConfig(num_workers=U, sigma=1.0, markov_rho=1.0)
    with pytest.raises(ValueError):
        ChannelConfig(num_workers=U, sigma=1.0, markov_rho=-0.1)


# ---------------------------------------------------------- participation

def test_participants_full_u_bitwise_equal_none():
    """participants=U activates the masked stats/combine/defense machinery;
    at a full mask every masked kernel is pinned bitwise-identical to its
    unmasked spelling, so the trajectories must agree exactly."""
    loss, params, dim, batches = tiny_problem()
    base = grid_cases(dim, 4) + [
        ScenarioCase("med", _floa(dim, Policy.EF, 1, 0.0), 0.05, seed=50,
                     defense=DefenseSpec(name="median")),
        ScenarioCase("krum", _floa(dim, Policy.EF, 1, 0.0), 0.05, seed=51,
                     defense=DefenseSpec(name="krum", num_byzantine=1)),
    ]
    full = [dataclasses.replace(c, participants=U) for c in base]
    a = SweepEngine(loss, SweepSpec.build(base)).run(params, batches)
    b = SweepEngine(loss, SweepSpec.build(full)).run(params, batches)
    _assert_bitwise(a, b)


def test_partial_lanes_run_and_differ():
    """K<U participation changes the trajectory and stays finite."""
    loss, params, dim, batches = tiny_problem()
    c_full = ScenarioCase("f", _floa(dim, Policy.BEV, 1), 0.05, seed=60)
    c_part = dataclasses.replace(c_full, participants=2)
    a = SweepEngine(loss, SweepSpec.build([c_full])).run(params, batches)
    b = SweepEngine(loss, SweepSpec.build([c_part])).run(params, batches)
    assert not np.allclose(a.loss, b.loss)
    assert np.all(np.isfinite(np.asarray(b.loss)))


def test_participants_validation():
    loss, params, dim, _ = tiny_problem()
    bad = ScenarioCase("b", _floa(dim, Policy.BEV, 1), 0.05, seed=1,
                       participants=U + 1)
    with pytest.raises(ValueError, match="participants"):
        SweepSpec.build([bad])
    with pytest.raises(ValueError, match="participants"):
        SweepSpec.build([dataclasses.replace(bad, participants=0)])
    # Defense arity must fit the PARTICIPATING cohort, not U.
    trm = ScenarioCase("t", _floa(dim, Policy.EF, 1, 0.0), 0.05, seed=2,
                       defense=DefenseSpec(name="trimmed_mean", trim=1),
                       participants=2)
    with pytest.raises(ValueError, match="trim"):
        SweepSpec.build([trm])
    kr = ScenarioCase("k", _floa(dim, Policy.EF, 1, 0.0), 0.05, seed=3,
                      defense=DefenseSpec(name="krum", num_byzantine=1),
                      participants=3)
    with pytest.raises(ValueError, match="participants"):
        SweepSpec.build([kr])


# ------------------------------------------------------------ directional

def test_cohort_of_one_omniscient_matches_strongest():
    """On identical worker shards with a noiseless channel, the honest mean
    equals the common gradient, so a single OMNISCIENT attacker's transmit
    vector coincides with the eq. 18 STRONGEST attack.  Only the addition
    order differs (post-combine injection vs in-superposition), so the match
    is allclose, not bitwise."""
    loss, params, dim, batches = tiny_problem()
    tiled = {k: np.tile(v[:, :v.shape[1] // U], (1, U, 1))
             for k, v in batches.items()}
    st = ScenarioCase("s", _floa(dim, Policy.CI, 1, noise=0.0), 0.05, seed=70)
    om = ScenarioCase("o", _floa(dim, Policy.CI, 1, noise=0.0,
                                 attack=AttackType.OMNISCIENT), 0.05, seed=70)
    res = SweepEngine(loss, SweepSpec.build([st, om])).run(params, tiled)
    np.testing.assert_allclose(np.asarray(res.loss[0]),
                               np.asarray(res.loss[1]), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(res.grad_norm[0]),
                               np.asarray(res.grad_norm[1]), rtol=2e-5)


def test_directional_lanes_finite_and_distinct():
    """Colluding and omniscient lanes run inside a mixed grid, stay finite,
    and produce trajectories distinct from STRONGEST and from each other."""
    loss, params, dim, batches = tiny_problem()
    mk = lambda n, atk, s: ScenarioCase(
        n, _floa(dim, Policy.BEV, 2, attack=atk), 0.05, seed=s)
    res = SweepEngine(loss, SweepSpec.build([
        mk("st", AttackType.STRONGEST, 80),
        mk("co", AttackType.COLLUDING, 80),
        mk("om", AttackType.OMNISCIENT, 80),
    ])).run(params, batches)
    assert np.all(np.isfinite(np.asarray(res.loss)))
    assert not np.allclose(res.loss[0], res.loss[1])
    assert not np.allclose(res.loss[0], res.loss[2])
    assert not np.allclose(res.loss[1], res.loss[2])


def test_directional_attacks_leave_legacy_lanes_bitwise():
    """Adding a colluding lane to a sweep leaves the other lanes' key streams
    and arithmetic untouched (the direction draw is a fold_in side-channel)."""
    loss, params, dim, batches = tiny_problem()
    legacy = grid_cases(dim, 2)
    ref = SweepEngine(loss, SweepSpec.build(legacy)).run(params, batches)
    mixed = legacy + [ScenarioCase(
        "co", _floa(dim, Policy.CI, 2, attack=AttackType.COLLUDING),
        0.05, seed=888)]
    got = SweepEngine(loss, SweepSpec.build(mixed)).run(params, batches)
    np.testing.assert_array_equal(np.asarray(got.loss[:2]),
                                  np.asarray(ref.loss))


# ---------------------------------------------------- engine equivalences

def test_all_axes_strict_flat_equals_tree():
    loss, params, dim, batches = tiny_problem()
    spec = SweepSpec.build(_axes_grid(dim))
    flat = SweepEngine(loss, spec,
                       plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches)
    tree = SweepEngine(loss, spec,
                       plan=ExecutionPlan(flat_state=False,
                                          strict_numerics=True)).run(
        params, batches)
    _assert_bitwise(flat, tree)


def test_all_axes_strict_grouped_equals_switch():
    loss, params, dim, batches = tiny_problem()
    spec = SweepSpec.build(_axes_grid(dim))
    grouped = SweepEngine(loss, spec,
                          plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches)
    switch = SweepEngine(loss, spec,
                         plan=ExecutionPlan(grouped_dispatch=False,
                                            strict_numerics=True)).run(
        params, batches)
    _assert_bitwise(grouped, switch)


def test_all_axes_strict_chunked_equals_monolithic():
    loss, params, dim, batches = tiny_problem()
    spec = SweepSpec.build(_axes_grid(dim))
    mono = SweepEngine(loss, spec,
                       plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches)
    ch = SweepEngine(loss, spec,
                     plan=ExecutionPlan(strict_numerics=True,
                                        chunk_rounds=3)).run(params, batches)
    _assert_bitwise(mono, ch)


def test_all_axes_single_device_mesh_matches_unsharded():
    """Degenerate 1-device shard_map over the tuple (flat, h) Markov carry —
    runs everywhere (tier-1)."""
    loss, params, dim, batches = tiny_problem()
    spec = SweepSpec.build(_axes_grid(dim))
    un = SweepEngine(loss, spec).run(params, batches)
    sh = SweepEngine(loss, spec,
                     plan=ExecutionPlan(mesh=make_sweep_mesh(1))).run(
        params, batches)
    _assert_close(sh, un)


@needs_8_devices
def test_all_axes_sharded_matches_unsharded():
    """8 fake devices: the mixed-axes grid (9 lanes, ghost-padded) matches
    the unsharded engine — the Markov h carry and participation masks shard
    with the lane axis."""
    loss, params, dim, batches = tiny_problem()
    spec = SweepSpec.build(_axes_grid(dim))
    un = SweepEngine(loss, spec).run(params, batches)
    sh = SweepEngine(loss, spec,
                     plan=ExecutionPlan(mesh=make_sweep_mesh(8))).run(
        params, batches)
    _assert_close(sh, un)


@needs_8_devices
def test_all_axes_sharded_strict_bitwise():
    loss, params, dim, batches = tiny_problem()
    spec = SweepSpec.build(_axes_grid(dim))
    un = SweepEngine(loss, spec,
                     plan=ExecutionPlan(strict_numerics=True)).run(
        params, batches)
    sh = SweepEngine(loss, spec,
                     plan=ExecutionPlan(mesh=make_sweep_mesh(8),
                                        strict_numerics=True)).run(
        params, batches)
    _assert_bitwise(sh, un)
