"""Distributed-runtime tests, run in subprocesses so the host device count
can be forced per-test (smoke tests must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# Each test spawns a fresh interpreter that recompiles its mesh program —
# tens of seconds apiece on CPU, so the whole module sits behind `slow`.
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_mesh_construction():
    out = run_py("""
        import jax
        jax.config.update("jax_threefry_partitionable", True)
        from repro.launch.mesh import make_debug_mesh, batch_axes, num_workers
        m = make_debug_mesh((4, 2), ("data", "model"))
        assert batch_axes(m) == ("data",)
        assert num_workers(m) == 4
        m3 = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
        assert batch_axes(m3) == ("pod", "data")
        assert num_workers(m3) == 4
        print("MESH_OK")
    """)
    assert "MESH_OK" in out


def test_train_step_compiles_and_runs_on_mesh():
    """Real (allocated) FLOA train step on a 4x2 mesh: runs 2 steps, loss
    finite, params change, FLOA state updates."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_threefry_partitionable", True)
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import make_train_step, init_model, init_floa_state
        from repro.configs import get_smoke
        mesh = make_debug_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(get_smoke("qwen3-4b"), model_parallel=2)
        shape = dict(seq_len=64, global_batch=8, kind="train")
        art = make_train_step(cfg, mesh, shape, alpha=0.05)
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        state = init_floa_state()
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 65), 0, cfg.vocab_size)}
        with mesh:
            step = jax.jit(art.fn, in_shardings=art.in_shardings)
            p1, s1, m1 = step(params, state, batch, jnp.uint32(0))
            p2, s2, m2 = step(p1, s1, batch, jnp.uint32(1))
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert np.isfinite(l1) and np.isfinite(l2), (l1, l2)
        assert l2 < l1 + 0.5
        d = float(jnp.sum(jnp.abs(p2["embed"] - params["embed"])))
        assert d > 0
        assert float(s2["eps2"]) != 1.0  # stats EMA updated
        print("TRAIN_OK", l1, l2)
    """)
    assert "TRAIN_OK" in out


def test_decode_step_on_mesh_matches_single_device():
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_threefry_partitionable", True)
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import make_decode_step, init_model
        from repro.models import transformer as T
        from repro.configs import get_smoke
        mesh = make_debug_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(get_smoke("starcoder2-3b"), model_parallel=2)
        shape = dict(seq_len=32, global_batch=8, kind="decode")
        art = make_decode_step(cfg, mesh, shape, "decode_32k")
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        caches = T.init_caches(cfg, 8, 32, window=cfg.window)
        toks = jax.random.randint(jax.random.PRNGKey(2), (8, 1), 0, cfg.vocab_size)
        with mesh:
            step = jax.jit(art.fn, in_shardings=art.in_shardings)
            logits_mesh, caches2 = step(params, caches, toks, jnp.int32(0))
        logits_1dev, _ = T.decode_step(params, T.init_caches(cfg, 8, 32, window=cfg.window), toks, jnp.int32(0), cfg, window=cfg.window)
        np.testing.assert_allclose(np.asarray(logits_mesh), np.asarray(logits_1dev), rtol=2e-3, atol=2e-3)
        print("DECODE_OK")
    """)
    assert "DECODE_OK" in out


def test_floa_weighted_loss_equals_vmap_aggregate():
    """The LLM-scale weighted-loss path must produce the same OTA aggregate
    as the paper-exact vmap(grad) path, given identical coefficients."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_threefry_partitionable", True)
        from repro.core.aggregation import per_worker_grads, _weighted_reduce
        U = 4
        def loss(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2)
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (6, 1))}
        batch = {"x": jax.random.normal(key, (U * 8, 6)),
                 "y": jax.random.normal(key, (U * 8, 1))}
        s = jnp.asarray([0.5, -0.2, 0.9, 0.1])
        # path 1: vmap per-worker grads then weighted reduce
        g_u, _ = per_worker_grads(loss, params, batch, U)
        agg1 = _weighted_reduce(g_u, s)
        # path 2: weighted scalar loss, single backward
        def per_ex(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2, axis=-1)
        def wloss(params):
            pe = per_ex(params, batch)
            pw = pe.reshape(U, -1).mean(1)
            return jnp.dot(s, pw)
        agg2 = jax.grad(wloss)(params)
        np.testing.assert_allclose(np.asarray(agg1["w"]), np.asarray(agg2["w"]), rtol=1e-5)
        print("EQUIV_OK")
    """, devices=1)
    assert "EQUIV_OK" in out


def test_seqsharded_decode_partial_softmax():
    """Flash-decoding combine over a sequence-sharded KV cache (shard_map)
    matches the single-device reference exactly."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.models.attention import decode_local_partial, combine_partials
        from repro.kernels.ref import decode_attention_ref
        mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
        B, H, KV, dh, S = 2, 8, 2, 32, 256
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, dh))
        k = jax.random.normal(ks[1], (B, S, KV, dh))
        v = jax.random.normal(ks[2], (B, S, KV, dh))
        pos = 200
        def inner(q_loc, k_loc, v_loc):
            sloc = k_loc.shape[1]
            start = jax.lax.axis_index("model") * sloc
            valid = jnp.broadcast_to((start + jnp.arange(sloc))[None, :] <= pos,
                                     (q_loc.shape[0], sloc))
            m, l, acc = decode_local_partial(q_loc, k_loc, v_loc, valid)
            return combine_partials(m, l, acc, ("model",))
        f = shard_map(inner, mesh=mesh,
                      in_specs=(P(), P(None, "model", None, None),
                                P(None, "model", None, None)),
                      out_specs=P(), check_rep=False)
        got = f(q, k, v)
        want = decode_attention_ref(q, k, v, jnp.int32(pos))
        err = float(jnp.max(jnp.abs(got - want.astype(jnp.float32))))
        assert err < 1e-5, err
        print("SEQSHARD_OK", err)
    """)
    assert "SEQSHARD_OK" in out


def test_multipod_mesh_lowering():
    """The pod axis shards: tiny config lowers+compiles on a (2,2,2) mesh."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp
        jax.config.update("jax_threefry_partitionable", True)
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import make_train_step
        from repro.configs import get_smoke
        mesh = make_debug_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = dataclasses.replace(get_smoke("granite-8b"), model_parallel=2)
        shape = dict(seq_len=32, global_batch=8, kind="train")
        art = make_train_step(cfg, mesh, shape)
        with mesh:
            compiled = jax.jit(art.fn, in_shardings=art.in_shardings).lower(*art.args).compile()
        assert compiled.cost_analysis() is not None
        print("MULTIPOD_OK")
    """)
    assert "MULTIPOD_OK" in out
