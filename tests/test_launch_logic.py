"""Pure-logic tests for the launch layer (no multi-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.steps import batch_shapes, decode_window
from repro.launch.sharding import fsdp_augment
from repro.models.common import ModelConfig


def test_batch_shapes_per_family():
    for arch in ARCH_IDS:
        cfg = get_config(arch, model_parallel=16)
        for name, shape in INPUT_SHAPES.items():
            if name in cfg.skip_shapes:
                continue
            b = batch_shapes(cfg, shape, shape["kind"])
            assert "tokens" in b
            assert b["tokens"].shape[0] == shape["global_batch"]
            if cfg.arch_type == "vlm":
                assert "embeds_prefix" in b
                total = b["embeds_prefix"].shape[1] + b["tokens"].shape[1] - (
                    1 if shape["kind"] != "prefill" else 0)
                assert total == shape["seq_len"]
            elif cfg.arch_type == "audio":
                assert b["frames"].shape[1] <= cfg.encdec.enc_seq_cap
            else:
                expect = shape["seq_len"] + (0 if shape["kind"] == "prefill" else 1)
                assert b["tokens"].shape[1] == expect


def test_decode_window_policy():
    # native SWA models keep their window everywhere
    sc = get_config("starcoder2-3b")
    assert decode_window(sc, "decode_32k") == 4096
    assert decode_window(sc, "long_500k") == 4096
    # full-attention dense models: window ONLY for long_500k
    qw = get_config("qwen3-4b")
    assert decode_window(qw, "decode_32k") is None
    assert decode_window(qw, "long_500k") == 8192
    # MLA: full attention even at 500k (compressed cache fits)
    ds = get_config("deepseek-v2-236b")
    assert decode_window(ds, "long_500k") is None
    # ssm has no attention windows at all
    mb = get_config("mamba2-1.3b")
    assert decode_window(mb, "long_500k") is None


def test_fsdp_augment_shards_large_leaves_only():
    import numpy as np
    from repro.launch.mesh import make_debug_mesh
    # fake 'mesh' with data axis of 4 — use jax devices trick not needed:
    # construct via Mesh of 1 device? fsdp_augment only reads mesh.shape.
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    specs = {"big": P(None, "model"), "small": P(None)}
    shapes = {
        "big": jax.ShapeDtypeStruct((1 << 12, 1 << 12), jnp.float32),  # 16M
        "small": jax.ShapeDtypeStruct((128,), jnp.float32),
    }
    out = fsdp_augment(specs, shapes, FakeMesh(), axis="data")
    assert out["big"] == P("data", "model")
    assert out["small"] == P(None)


def test_fsdp_augment_skips_leading_scan_dim():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    specs = {"stacked": P(None, None, "model")}
    shapes = {"stacked": jax.ShapeDtypeStruct((60, 4096, 4096), jnp.bfloat16)}
    out = fsdp_augment(specs, shapes, FakeMesh(), axis="data")
    # dim0 (layer stack) untouched; dim1 gets the data axis
    assert out["stacked"] == P(None, "data", "model")


def test_probe_extrapolation_math():
    from repro.launch.dryrun import probe_costs  # noqa: F401 (import check)
    # linear model: f(L) = 7 + 3L measured at L=1,2 -> predict L=60
    def ext(v1, v2, n):
        body = v2 - v1
        return max(v1 - body, 0.0) + body * n

    assert ext(10.0, 13.0, 60) == pytest.approx(7 + 3 * 60)


def test_skip_shapes_enforced():
    from repro.configs import shape_applicable
    cfg = get_config("seamless-m4t-large-v2")
    assert not shape_applicable(cfg, "long_500k")
    assert shape_applicable(cfg, "decode_32k")
    for arch in ARCH_IDS:
        if arch == "seamless-m4t-large-v2":
            continue
        assert shape_applicable(get_config(arch), "long_500k"), arch
