"""Fig. 2 — single WEAK attacker (lowest channel gain), alpha_hat ∈ {0.1,1,2}.

Paper claims (§IV-B): both converge at alpha_hat<=1 (BEV faster at 1, since
Omega_BEV > Omega_CI dominates at large lr); at alpha_hat=2 CI fails but BEV
still converges; at 0.1 CI is slightly better.
All six setups run as one compiled sweep (6 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_figure

WEAK_SIGMA = 0.3  # attacker channel scale << honest sigma=1.0


def main(rounds: int = 150) -> dict:
    exps = [Experiment(name=f"{name}@ah{ah}", policy=pol, n_attackers=1,
                       alpha_hat=ah, attacker_sigma=WEAK_SIGMA, rounds=rounds)
            for ah in (0.1, 1.0, 2.0)
            for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]]
    out = run_figure(exps)
    for name, logs in out.items():
        print_csv("fig2", name, logs)
    return out


if __name__ == "__main__":
    main()
