"""Fig. 2 — single WEAK attacker (lowest channel gain), alpha_hat ∈ {0.1,1,2}.

Paper claims (§IV-B): both converge at alpha_hat<=1 (BEV faster at 1, since
Omega_BEV > Omega_CI dominates at large lr); at alpha_hat=2 CI fails but BEV
still converges; at 0.1 CI is slightly better.
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_experiment

WEAK_SIGMA = 0.3  # attacker channel scale << honest sigma=1.0


def main(rounds: int = 150) -> dict:
    out = {}
    for ah in (0.1, 1.0, 2.0):
        for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]:
            exp = Experiment(name=f"{name}@ah{ah}", policy=pol, n_attackers=1,
                             alpha_hat=ah, attacker_sigma=WEAK_SIGMA,
                             rounds=rounds)
            logs = run_experiment(exp)
            print_csv("fig2", exp, logs)
            out[exp.name] = logs
    return out


if __name__ == "__main__":
    main()
