"""Fig. 2 — single WEAK attacker (lowest channel gain), alpha_hat ∈ {0.1,1,2}.

Paper claims (§IV-B): both converge at alpha_hat<=1 (BEV faster at 1, since
Omega_BEV > Omega_CI dominates at large lr); at alpha_hat=2 CI fails but BEV
still converges; at 0.1 CI is slightly better.
All six setups run as one compiled sweep (6 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, run_figure
from benchmarks.render_tables import print_sweep_csv

WEAK_SIGMA = 0.3  # attacker channel scale << honest sigma=1.0


def main(rounds: int = 150):
    exps = [Experiment(name=f"{name}@ah{ah}", policy=pol, n_attackers=1,
                       alpha_hat=ah, attacker_sigma=WEAK_SIGMA, rounds=rounds)
            for ah in (0.1, 1.0, 2.0)
            for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]]
    result = run_figure(exps)
    print_sweep_csv("fig2", result, eval_every=10)
    return result


if __name__ == "__main__":
    main()
