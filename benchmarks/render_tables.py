"""Render EXPERIMENTS.md tables from results/dryrun + results/perf JSONs,
figure CSV/markdown straight from an in-memory `SweepResult`, and the
sweep-engine throughput table from BENCH_sweep.json.

  PYTHONPATH=src python -m benchmarks.render_tables            # prints md

The figure scripts hand their `SweepResult` to `print_sweep_csv` /
`sweep_markdown` directly — no per-experiment CSV intermediates (the
RoundLog sampling lives in `SweepResult.logs`, so the schedule matches the
legacy per-experiment writers row for row).
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath):
    out = {}
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            d = json.load(f)
        out[(d.get("arch"), d.get("shape"), d.get("mesh"))] = d
    return out


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}"
    return f"{x:.2e}"


def sweep_csv_rows(tag, result, eval_every: int = 1):
    """`fig,experiment,round,loss,accuracy` rows from a SweepResult."""
    for name in result.names:
        for lg in result.logs(name, eval_every):
            yield f"{tag},{name},{lg.step},{lg.loss:.5f},{lg.accuracy:.4f}"


def print_sweep_csv(tag, result, eval_every: int = 1) -> None:
    """Figure-script CSV writer fed by the SweepResult itself."""
    for row in sweep_csv_rows(tag, result, eval_every):
        print(row)


def sweep_markdown(result, eval_every: int = 1) -> str:
    """Per-scenario final-round summary table from a SweepResult."""
    lines = ["| scenario | final loss | final accuracy | final grad norm |",
             "|---|---|---|---|"]
    for name in result.names:
        logs = result.logs(name, eval_every)
        last = logs[-1]
        acc = "-" if last.accuracy != last.accuracy else f"{last.accuracy:.4f}"
        lines.append(f"| {name} | {last.loss:.5f} | {acc} | "
                     f"{last.grad_norm:.4f} |")
    return "\n".join(lines)


def sweep_bench_table(path: str = "BENCH_sweep.json") -> str:
    """Engine-throughput table from sweep_bench.py's JSON record."""
    with open(path) as f:
        d = json.load(f)
    lines = [
        f"S={d['scenarios']} x R={d['rounds']}, D={d['dim']}, "
        f"backend={d['backend']}, devices={d['devices']} "
        f"(speedups vs {d['baseline']})",
        "",
        "| engine | cold rounds/s | warm rounds/s | cold speedup | warm speedup |",
        "|---|---|---|---|---|",
    ]
    for name, e in d["engines"].items():
        lines.append(
            f"| {name} | {e['cold_rounds_per_sec']:.1f} | "
            f"{e['warm_rounds_per_sec']:.1f} | {e['cold_speedup']:.2f}x | "
            f"{e['warm_speedup']:.2f}x |")
    if d.get("defenses"):
        lines += [
            "",
            "Defense-code lanes (flat engine; lanes per row, rounds shared "
            "within one bench run):",
            "",
            "| defense lane | lanes | rounds | cold rounds/s | warm rounds/s |",
            "|---|---|---|---|---|",
        ]
        for name, e in d["defenses"].items():
            lines.append(
                f"| {name} | {e['lanes']} | {e['rounds']} | "
                f"{e['cold_rounds_per_sec']:.1f} | "
                f"{e['warm_rounds_per_sec']:.1f} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful | HBM GB/dev | move-the-bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        ("moe", "train"): "capacity-gather dispatch cuts the E/top_k masked overcompute (see §Perf B1)",
        ("memory_s", "decode"): "int8 KV cache halves the cache stream (§Perf B2)",
        ("collective_s", "train"): "head-divisible sharding / fewer seq all-gathers (§Perf B3)",
        ("collective_s", "prefill"): "batch-only residual layout removes per-layer seq gathers (§Perf B3)",
        ("memory_s", "train"): "bytes-accessed is XLA's pre-fusion bound; fusion + remat tuning",
        ("memory_s", "prefill"): "fused attention keeps scores out of HBM",
        ("collective_s", "decode"): "sequence-sharded cache + partial-softmax combine",
    }
    for (arch, shape, mesh), d in sorted(recs.items()):
        if mesh != "single":
            continue
        if d.get("status") == "skip":
            lines.append(f"| {arch} | {shape} | - | - | - | skip | - | - | "
                         f"{d.get('reason','')} |")
            continue
        if d.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | - | - | - | {d.get('status')} "
                         f"| - | - | |")
            continue
        t = d["roofline"]
        mem = d.get("memory") or {}
        hbm = sum(v for v in (mem.get("argument_size"), mem.get("temp_size"),
                              mem.get("output_size")) if v) / 1e9
        ur = d.get("useful_ratio")
        kind = "train" if shape.startswith("train") else (
            "prefill" if shape.startswith("prefill") else "decode")
        moe = d.get("n_active", 1) < d.get("n_params", 1)
        hint = hints.get(("moe", kind)) if (moe and kind == "train") else None
        hint = hint or hints.get((d["dominant"], kind), "")
        lines.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{d['dominant'].replace('_s','')} | "
            f"{ur if ur is None else round(ur,3)} | {hbm:.1f} | {hint} |")
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | single-pod | multi-pod | compile s/m | bytes/dev (arg+temp) |",
        "|---|---|---|---|---|---|",
    ]
    archs = sorted({a for a, _, _ in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for a in archs:
        for s in shapes:
            d1 = recs.get((a, s, "single"))
            d2 = recs.get((a, s, "multi"))
            if d1 is None and d2 is None:
                continue
            st1 = d1.get("status") if d1 else "-"
            st2 = d2.get("status") if d2 else "-"
            cs = f"{d1.get('compile_s','-') if d1 else '-'}/" \
                 f"{d2.get('compile_s','-') if d2 else '-'}"
            mem = (d1 or d2).get("memory") or {}
            gb = sum(v for v in (mem.get("argument_size"),
                                 mem.get("temp_size")) if v) / 1e9
            lines.append(f"| {a} | {s} | {st1} | {st2} | {cs} | {gb:.1f} GB |")
    return "\n".join(lines)


def main() -> None:
    if os.path.exists("BENCH_sweep.json"):
        print("### Sweep-engine throughput (BENCH_sweep.json)\n")
        print(sweep_bench_table())
        print()
    recs = load("results/dryrun")
    print("### Dry-run status (80 combos)\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single-pod, per step)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
