"""Fig. 4 — N ∈ {1,2,3,4} random attackers (iso channels).

Paper claims (§IV-D): with N < 4 both converge (slower as N grows); at N=4
(> U/(1+sqrt(pi U)) = 1.51 for U=10) CI diverges while BEV (threshold U/2=5)
still converges in the right direction, slower.
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_experiment


def main(rounds: int = 150) -> dict:
    out = {}
    for n in (1, 2, 3, 4):
        for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]:
            exp = Experiment(name=f"{name}@N{n}", policy=pol, n_attackers=n,
                             alpha_hat=0.1, rounds=rounds)
            logs = run_experiment(exp)
            print_csv("fig4", exp, logs)
            out[exp.name] = logs
    return out


if __name__ == "__main__":
    main()
