"""Fig. 4 — N ∈ {1,2,3,4} random attackers (iso channels).

Paper claims (§IV-D): with N < 4 both converge (slower as N grows); at N=4
(> U/(1+sqrt(pi U)) = 1.51 for U=10) CI diverges while BEV (threshold U/2=5)
still converges in the right direction, slower.
All eight setups run as one compiled sweep (8 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, run_figure
from benchmarks.render_tables import print_sweep_csv


def main(rounds: int = 150):
    exps = [Experiment(name=f"{name}@N{n}", policy=pol, n_attackers=n,
                       alpha_hat=0.1, rounds=rounds)
            for n in (1, 2, 3, 4)
            for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]]
    result = run_figure(exps)
    print_sweep_csv("fig4", result, eval_every=10)
    return result


if __name__ == "__main__":
    main()
