"""Sweep-engine throughput: looped FLTrainer vs scan vs scan+vmap.

Runs the same S-scenario x R-round grid (fig-4 style: CI/BEV x attacker
count on the paper MLP, D=50890) through three execution strategies:

  looped     FLTrainer.run         — one jitted dispatch per round, and one
                                     fresh compile per scenario (the config
                                     is baked into each trainer's closure):
                                     the seed repo's only mode
  scan       FLTrainer.run_scan    — rounds compiled into one lax.scan,
                                     still one program (compile) per scenario
  scan+vmap  fl.sweep.SweepEngine  — rounds scanned AND scenarios stacked
                                     into one vmapped lane axis: the whole
                                     grid is ONE compile, ONE dispatch

Two aggregate rounds/sec (S*R / wall) numbers per engine:

  cold   end-to-end including compilation — what a figure script actually
         pays to produce its grid once.  The looped/scan baselines pay S
         compiles; the sweep engine pays one, so its advantage GROWS with S.
  warm   steady-state rerun of the already-compiled program(s) — isolates
         per-round dispatch/batching efficiency.

  PYTHONPATH=src:. python benchmarks/sweep_bench.py [--rounds R] [--scenarios S]
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import (
    Experiment,
    Policy,
    experiment_floa,
    figure_setup,
)
from repro.data import FederatedSampler
from repro.fl import FLTrainer, ScenarioCase, SweepEngine, SweepSpec
from repro.models.mlp import mlp_loss


def grid(num: int, rounds: int):
    """CI/BEV x attacker-count grid, fig-4 style, cycled to `num` lanes."""
    cells = [(pol, n) for n in (0, 1, 2, 3, 4)
             for pol in (Policy.CI, Policy.BEV)]
    return [Experiment(name=f"{cells[i % len(cells)][0].value}"
                            f"@N{cells[i % len(cells)][1]}#{i}",
                       policy=cells[i % len(cells)][0],
                       n_attackers=cells[i % len(cells)][1],
                       alpha_hat=0.1, rounds=rounds, seed=100 + i)
            for i in range(num)]


def main(rounds: int = 25, scenarios: int = 16) -> dict:
    mc, shards, params, _ = figure_setup()
    exps = grid(scenarios, rounds)
    cfgs = [experiment_floa(e, mc) for e in exps]
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)

    class Replay:
        """Feed the looped trainer the same pre-staged batches the scan
        engines consume, so the timers isolate engine overhead rather than
        charging host-side numpy sampling to the looped path only."""

        def __init__(self):
            self.t = 0

        def next_round(self):
            out = {k: v[self.t % rounds] for k, v in batches.items()}
            self.t += 1
            return out

    total = len(exps) * rounds
    cold, warm = {}, {}

    def run_looped(trainers):
        for tr, e in zip(trainers, exps):
            p, _ = tr.run(params, Replay(), rounds,
                          jax.random.PRNGKey(e.seed), eval_every=0)
            jax.block_until_ready(p)

    def run_scans(trainers):
        for tr, e in zip(trainers, exps):
            # run_scan syncs internally (round losses come back as np arrays)
            tr.run_scan(params, batches, jax.random.PRNGKey(e.seed),
                        eval_every=0)

    # --- looped: fresh trainers => one compile per scenario, then per-round
    # dispatch; warm rerun reuses the compiled round_steps.
    trainers = [FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha)
                for floa, alpha in cfgs]
    t0 = time.perf_counter()
    run_looped(trainers)
    cold["looped"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_looped(trainers)
    warm["looped"] = time.perf_counter() - t0

    # --- scan: one lax.scan program (compile) per scenario.
    trainers = [FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha)
                for floa, alpha in cfgs]
    t0 = time.perf_counter()
    run_scans(trainers)
    cold["scan"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_scans(trainers)
    warm["scan"] = time.perf_counter() - t0

    # --- scan+vmap: the whole grid as one program, one compile.
    t0 = time.perf_counter()
    spec = SweepSpec.build([
        ScenarioCase(e.name, floa, alpha, seed=e.seed)
        for e, (floa, alpha) in zip(exps, cfgs)
    ])
    engine = SweepEngine(mlp_loss, spec)
    engine.run(params, batches)
    cold["scan+vmap"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    engine.run(params, batches)
    warm["scan+vmap"] = time.perf_counter() - t0

    print(f"# paper MLP (D={mc.dim}), S={len(exps)} scenarios x R={rounds} "
          f"rounds, backend={jax.default_backend()}")
    print("engine,cold_rounds_per_sec,warm_rounds_per_sec,"
          "cold_speedup_vs_looped,warm_speedup_vs_looped")
    out = {}
    for name in ("looped", "scan", "scan+vmap"):
        c, w = total / cold[name], total / warm[name]
        out[name] = dict(cold=c, warm=w,
                         cold_speedup=cold["looped"] / cold[name],
                         warm_speedup=warm["looped"] / warm[name])
        print(f"{name},{c:.1f},{w:.1f},"
              f"{out[name]['cold_speedup']:.2f}x,"
              f"{out[name]['warm_speedup']:.2f}x")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--scenarios", type=int, default=16)
    args = ap.parse_args()
    main(rounds=args.rounds, scenarios=args.scenarios)
