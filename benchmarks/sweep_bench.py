"""Sweep-engine throughput: looped FLTrainer vs scan vs tree-state vs flat.

Runs the same S-scenario x R-round grid (fig-4 style: CI/BEV x attacker
count on the paper MLP, D=50890) through the execution strategies:

  looped      FLTrainer.run        — one jitted dispatch per round, and one
                                     fresh compile per scenario (the config
                                     is baked into each trainer's closure):
                                     the seed repo's only mode
  scan        FLTrainer.run_scan   — rounds compiled into one lax.scan,
                                     still one program (compile) per scenario
  scan+vmap   SweepEngine(flat_state=False)
                                   — rounds scanned AND scenarios stacked
                                     into one vmapped lane axis (the PR 1
                                     engine): per round it still pays the
                                     [S, U, D] flatten/concat and a per-leaf
                                     unflatten + update
  flat        SweepEngine          — the flat-state warm path: params stay
                                     one [S, D] matrix across the scan and
                                     the combine + PS update fuse into
                                     `batched_floa_step`
  flat+chunk  SweepEngine(chunk_rounds=C)
                                   — scan-of-chunks: outer Python loop over
                                     ceil(R/C) inner C-round scans (same
                                     trajectories; [C, ...] batch blocks
                                     staged per chunk instead of the whole
                                     [R, ...] stack living on device)
  flat+chunk+async
              SweepEngine(chunk_rounds=C, async_staging=True)
                                   — chunked with double-buffered staging:
                                     chunk k+1's block is sliced host-side
                                     and device_put (async) while chunk k
                                     computes; the A/B against flat+chunk
                                     isolates the input-pipeline overlap
                                     (expect wins on data-bound configs —
                                     large batch blocks relative to round
                                     compute — and noise-level parity on
                                     compute-bound ones like this MLP grid)
  flat+shmap  SweepEngine(mesh=...)
                                   — the flat scan shard_mapped over a
                                     ("data",) mesh (enable with --sharded;
                                     on CPU hosts set
                                     XLA_FLAGS=--xla_force_host_platform_device_count=8
                                     BEFORE launching to fan the lane axis
                                     over 8 fake devices)

Two aggregate rounds/sec (S*R / wall) numbers per engine:

  cold   end-to-end including compilation — what a figure script actually
         pays to produce its grid once.  The looped/scan baselines pay S
         compiles; the sweep engines pay one, so their advantage GROWS with S.
  warm   steady-state rerun of the already-compiled program(s) — isolates
         per-round dispatch/batching efficiency (best of --reps reruns, since
         shared CI boxes are noisy).

--defenses additionally benches the defense-code lane axis: one flat-state
engine per defense family (analog FLOA reference, mean, median, trimmed-mean,
(multi-)Krum, geometric median) plus the mixed all-families grid — under the
default GROUPED dispatch ("mixed": static lane partition by defense code,
each family's kernel runs once over its own sub-slab) and the PR-3 per-lane
lax.switch reference ("mixed_switch": every family computed for every lane) —
each at --defense-scenarios lanes x --defense-rounds rounds (its own knobs —
the screening kernels add sort/pairwise-distance work per round, so the
defense section is sized explicitly rather than inheriting the headline
shape), with per-defense cold/warm rounds-per-sec recorded under the JSON's
"defenses" key and the grouped-vs-switch warm speedup at the top level.

--scenario-axes benches the adaptive-adversary lane axes: one engine each
for the legacy CI/BEV x STRONGEST grid, Gauss-Markov fading (the (state, h)
scan-carry tuple), K-of-U participation (masked stats/combine/screening),
colluding/omniscient directional cohorts (post-combine payload injection),
and the all-axes mixed spec — recorded under the JSON's "scenario_axes" key so
the cross-axis trace tax is tracked (each axis is a trace-time decision
for the whole sweep).

--resume benches the preemption-safety machinery: the checkpointed chunked
engine (ExecutionPlan(checkpoint_dir=...) committing the full resume carry
at every chunk boundary) A/B'd against the plain chunked engine on the same
grid — the warm-rows ratio is the checkpoint tax — plus the wall time of a
`run(resume=True)` restoring off the latest committed boundary, and a
persistent-compilation-cache warm-restart pair: two fresh subprocesses run
the same tiny sweep against one $REPRO_COMPILATION_CACHE directory, the
first populating it cold and the second restarting warm (the
cache-hit path a resumed fleet takes).  Recorded under the JSON's "resume"
key; the perf gate checks the chunked/chunked_ckpt warm rows shape-aware
(lanes/rounds/chunk_rounds/dim must match the baseline, else skipped) and
never gates the subprocess cache timings (they are machine-noise bound).

--workers benches the worker-population scaling series: the mixed-defense
worker grid (analog FLOA + median / trimmed-mean / Krum lanes) at each U in
--workers-series (default 10,1000,10000) on a deliberately tiny MLP, both
unsharded and worker-sharded over every visible device
(ExecutionPlan(mesh=make_sweep_mesh(n, worker_shards=n)) — the OTA combine
as a psum over worker shards), recorded under the JSON's "workers" key.
The perf gate skips workers rows whose (u, lanes, rounds, dim,
worker_shards) shape differs from the baseline's instead of failing them.

Results are printed as CSV and written to a machine-readable JSON
(--out, default BENCH_sweep.json) so the perf trajectory is tracked across
PRs; the CI sweep-sharded job uploads it as a workflow artifact AND gates on
it: --check-against BASELINE.json --tolerance 0.5 compares every fresh warm
rounds/sec row against the committed baseline and exits non-zero when a row
drops below baseline * (1 - tolerance) — silent throughput regressions in
the defense hot path fail the build instead of landing.

  PYTHONPATH=src:. python benchmarks/sweep_bench.py [--rounds R] [--scenarios S]
      [--sharded] [--reps N] [--skip-looped] [--defenses]
      [--defense-rounds R] [--defense-scenarios S] [--chunk-rounds C]
      [--resume] [--resume-rounds R] [--resume-lanes S]
      [--out BENCH_sweep.json]
      [--check-against BENCH_sweep.json] [--tolerance 0.5]

See docs/benchmarks.md for how to read BENCH_sweep.json, what the CI
`--check-against --tolerance 0.5` perf gate does, and how to regenerate the
committed baseline when a PR legitimately changes throughput.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    Experiment,
    Policy,
    experiment_floa,
    figure_setup,
)
from repro import make_sweep_mesh
from repro.core import AttackConfig, AttackType, ChannelConfig, FLOAConfig
from repro.core import DefenseSpec, PowerConfig, first_n_mask
from repro.data import FederatedSampler
from repro.fl import (
    ExecutionPlan,
    FLTrainer,
    ScenarioCase,
    SweepEngine,
    SweepSpec,
)
from repro.models import mlp_loss

DEFENSE_FAMILIES = [
    ("floa", None),  # analog reference lanes (BEV policy)
    ("mean", DefenseSpec(name="mean")),
    ("median", DefenseSpec(name="median")),
    ("trimmed_mean", DefenseSpec(name="trimmed_mean", trim=3)),
    ("krum", DefenseSpec(name="krum", num_byzantine=3)),
    ("multi_krum", DefenseSpec(name="multi_krum", num_byzantine=3, multi=3)),
    ("geometric_median", DefenseSpec(name="geometric_median")),
]


def defense_grid(mc, family: str, spec, num: int):
    """`num` lanes of one defense family across attacker counts 0..4."""
    u, d = mc.num_workers, mc.dim
    cases = []
    for i in range(num):
        n = i % 5
        floa = FLOAConfig(
            channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=0.0),
            power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max,
                              policy=Policy.BEV if spec is None else Policy.EF),
            attack=AttackConfig(
                attack=AttackType.STRONGEST if n else AttackType.NONE,
                byzantine_mask=first_n_mask(u, n)))
        cases.append(ScenarioCase(
            f"{family}@N{n}#{i}", floa, 0.05, seed=300 + i,
            defense=spec if spec is not None else DefenseSpec()))
    return cases


def bench_defenses(mc, shards, params, rounds: int, scenarios: int,
                   reps: int) -> dict:
    """Per-defense-family engine throughput (cold + interleaved best-of warm),
    plus the mixed grid with every family as lanes of ONE program — under the
    default grouped dispatch ("mixed") and the PR-3 per-lane lax.switch
    reference ("mixed_switch"), so BENCH_sweep.json records the grouped-
    dispatch speedup on the grid where it matters."""
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)
    grids = [(name, defense_grid(mc, name, spec, scenarios), ExecutionPlan())
             for name, spec in DEFENSE_FAMILIES]
    mixed = [c for _, cases, _ in grids for c in cases[:max(1, scenarios // 2)]]
    grids.append(("mixed", mixed, ExecutionPlan()))
    grids.append(("mixed_switch", mixed, ExecutionPlan(grouped_dispatch=False)))

    cold, runners = {}, []
    for name, cases, plan in grids:
        engine = SweepEngine(mlp_loss, SweepSpec.build(cases), plan=plan)
        run_once = (lambda e=engine: e.run(params, batches))
        t0 = time.perf_counter()
        run_once()
        cold[name] = time.perf_counter() - t0
        runners.append((name, len(cases), run_once))

    best = {name: float("inf") for name, _, _ in runners}
    for _ in range(reps):
        for name, _, run_once in runners:
            t0 = time.perf_counter()
            run_once()
            best[name] = min(best[name], time.perf_counter() - t0)

    print(f"# defense lanes: R={rounds} rounds x S={scenarios} lanes/family "
          f"(mixed: {len(mixed)}), D={mc.dim}, U={mc.num_workers}")
    print("defense,lanes,cold_rounds_per_sec,warm_rounds_per_sec")
    out = {}
    for name, lanes, _ in runners:
        total = lanes * rounds
        out[name] = dict(
            lanes=lanes, rounds=rounds,
            cold_rounds_per_sec=round(total / cold[name], 2),
            warm_rounds_per_sec=round(total / best[name], 2))
        print(f"{name},{lanes},{out[name]['cold_rounds_per_sec']:.1f},"
              f"{out[name]['warm_rounds_per_sec']:.1f}")
    return out


MARKOV_RHO = 0.9


def scenario_axes_grid(mc, axes: str, num: int):
    """`num` lanes exercising one adaptive-adversary axis (or all of them):
    `legacy` is the plain CI/BEV x STRONGEST grid, `markov` adds rho=0.9
    Gauss-Markov fading, `participation` samples K=U-3 of U clients per
    round, `directional` alternates COLLUDING/OMNISCIENT cohorts, and
    `mixed_axes` stacks all of it in one spec (the worst-case trace)."""
    u, d = mc.num_workers, mc.dim
    cases = []
    for i in range(num):
        n = i % 4 + (0 if axes in ("legacy", "markov", "participation")
                     else 1)
        rho = MARKOV_RHO if axes in ("markov", "mixed_axes") and i % 2 else 0.0
        part = u - 3 if axes in ("participation", "mixed_axes") and i % 3 \
            else None
        if axes == "directional" or (axes == "mixed_axes" and i % 2):
            attack = (AttackType.COLLUDING if i % 4 < 2
                      else AttackType.OMNISCIENT)
        else:
            attack = AttackType.STRONGEST if n else AttackType.NONE
        floa = FLOAConfig(
            channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=0.05,
                                  markov_rho=rho),
            power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max,
                              policy=Policy.BEV if i % 2 else Policy.CI),
            attack=AttackConfig(attack=attack,
                                byzantine_mask=first_n_mask(u, n)))
        cases.append(ScenarioCase(f"{axes}@N{n}#{i}", floa, 0.05,
                                  seed=500 + i, participants=part))
    return cases


def bench_scenario_axes(mc, shards, params, rounds: int, scenarios: int,
                        reps: int) -> dict:
    """Adaptive-adversary axis throughput (--scenario-axes): what each new
    lane axis costs on top of the legacy grid.  `markov` pays the (state, h)
    scan-carry tuple, `participation` the masked stats/combine/screening
    reductions, `directional` the post-combine payload injection, and
    `mixed_axes` all three in one program — each axis is a trace-time
    decision for the whole sweep, so these rows bound the cross-axis tax."""
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)
    grids = [(name, scenario_axes_grid(mc, name, scenarios))
             for name in ("legacy", "markov", "participation", "directional",
                          "mixed_axes")]
    cold, runners = {}, []
    for name, cases in grids:
        engine = SweepEngine(mlp_loss, SweepSpec.build(cases))
        run_once = (lambda e=engine: e.run(params, batches))
        t0 = time.perf_counter()
        run_once()
        cold[name] = time.perf_counter() - t0
        runners.append((name, len(cases), run_once))

    best = {name: float("inf") for name, _, _ in runners}
    for _ in range(reps):
        for name, _, run_once in runners:
            t0 = time.perf_counter()
            run_once()
            best[name] = min(best[name], time.perf_counter() - t0)

    print(f"# scenario axes: R={rounds} rounds x S={scenarios} lanes/axis, "
          f"D={mc.dim}, U={mc.num_workers}")
    print("axis,lanes,cold_rounds_per_sec,warm_rounds_per_sec")
    out = {}
    for name, lanes, _ in runners:
        total = lanes * rounds
        out[name] = dict(
            lanes=lanes, rounds=rounds,
            cold_rounds_per_sec=round(total / cold[name], 2),
            warm_rounds_per_sec=round(total / best[name], 2))
        print(f"{name},{lanes},{out[name]['cold_rounds_per_sec']:.1f},"
              f"{out[name]['warm_rounds_per_sec']:.1f}")
    return out


def worker_grid(u: int, dim: int):
    """Mixed-defense lanes at worker population U: one analog FLOA (BEV)
    lane plus median / trimmed-mean / Krum screening lanes, U//10 STRONGEST
    attackers — the large-U showdown in miniature, exercising the psum OTA
    combine and every large-U defense routing tier at once."""
    n_atk = max(1, u // 10)
    fams = [None,
            DefenseSpec(name="median"),
            DefenseSpec(name="trimmed_mean", trim=n_atk),
            DefenseSpec(name="krum", num_byzantine=n_atk)]
    cases = []
    for i, spec in enumerate(fams):
        floa = FLOAConfig(
            channel=ChannelConfig(num_workers=u, sigma=1.0,
                                  noise_std=0.05 if spec is None else 0.0),
            power=PowerConfig(num_workers=u, dim=dim, p_max=1.0,
                              policy=Policy.BEV if spec is None
                              else Policy.EF),
            attack=AttackConfig(attack=AttackType.STRONGEST,
                                byzantine_mask=first_n_mask(u, n_atk)))
        name = "floa" if spec is None else spec.name
        cases.append(ScenarioCase(f"{name}@U{u}", floa, 0.05, seed=400 + i,
                                  defense=spec if spec is not None
                                  else DefenseSpec()))
    return cases


def bench_workers(series, rounds: int, reps: int) -> dict:
    """U-scaling series (--workers): the mixed-defense worker grid at each
    U in `series`, unsharded AND worker-sharded over every visible device
    (the sharded row is skipped on single-device hosts).  A deliberately
    tiny MLP (D~260) keeps the model-side work flat so the rows isolate how
    the engine scales with the worker population: per-worker gradient
    production, the standardization handshake, the OTA combine, and the
    large-U defense kernels (U=10 unrolled sort / direct Krum, U=1e3
    bitonic / blocked Krum, U=1e4 jnp.sort fallback / blocked Krum).
    Timing reps are capped at 2 for this section: the U=1e4 rows are
    minutes-per-rep on a CPU box and best-of-2 is enough for a gate with
    0.5 tolerance.  On a CPU backend the sharded row is additionally
    skipped from U=1e4 up (marked `sharded_skipped` in the record): the
    digital screening lanes recompute their defense on every shard after
    the sub-slab all-gather, so 8 emulated devices on a 2-core box do 8x
    the work serially — tens of minutes for a row that measures thread
    thrash, not the engine."""
    d_in, d_h = 16, 4
    dim = d_in * d_h + d_h
    reps = min(reps, 2)

    def loss(params, b):
        pred = jax.nn.relu(b["x"] @ params["w1"]) @ params["w2"]
        return jnp.mean((pred - b["y"]) ** 2)

    k = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(k, (d_in, d_h)),
              "w2": jax.random.normal(k, (d_h, 1))}
    shards_w = jax.device_count()
    out = {}
    print(f"# worker scaling: U series {list(series)}, D={dim}, "
          f"R={rounds} rounds, worker_shards={shards_w}")
    print("u,engine,lanes,cold_rounds_per_sec,warm_rounds_per_sec")
    for u in series:
        rng = np.random.default_rng(u)
        batches = {
            "x": rng.normal(size=(rounds, u, d_in)).astype(np.float32),
            "y": rng.normal(size=(rounds, u, 1)).astype(np.float32)}
        spec = SweepSpec.build(worker_grid(u, dim))
        engines = {"unsharded": SweepEngine(loss, spec)}
        row = dict(u=u, lanes=len(spec), rounds=rounds, dim=dim,
                   worker_shards=shards_w)
        if shards_w > 1:
            if u >= 10_000 and jax.default_backend() == "cpu":
                row["sharded_skipped"] = "cpu-emulated collectives"
                print(f"{u},sharded,{len(spec)},skipped (cpu-emulated "
                      "collectives)")
            else:
                engines["sharded"] = SweepEngine(
                    loss, spec, plan=ExecutionPlan(
                        mesh=make_sweep_mesh(shards_w,
                                             worker_shards=shards_w)))
        for name, engine in engines.items():
            t0 = time.perf_counter()
            engine.run(params, batches)
            cold = time.perf_counter() - t0
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                engine.run(params, batches)
                best = min(best, time.perf_counter() - t0)
            total = len(spec) * rounds
            row[name] = dict(cold_rounds_per_sec=round(total / cold, 2),
                             warm_rounds_per_sec=round(total / best, 2))
            print(f"{u},{name},{len(spec)},"
                  f"{row[name]['cold_rounds_per_sec']:.1f},"
                  f"{row[name]['warm_rounds_per_sec']:.1f}")
        out[f"U{u}"] = row
    return out


def lm_grid(u: int, dim: int, n_atk: int):
    """The LM-lane showdown in miniature: one analog BEV lane plus a median
    screening lane, STRONGEST attackers in both — the two defense routing
    tiers (shard-local columnwise vs analog OTA) that dominate the
    real-model lanes."""
    lanes = [("floa", None), ("median", DefenseSpec(name="median"))]
    cases = []
    for i, (name, spec) in enumerate(lanes):
        floa = FLOAConfig(
            channel=ChannelConfig(num_workers=u, sigma=1.0,
                                  noise_std=0.05 if spec is None else 0.0),
            power=PowerConfig(num_workers=u, dim=dim, p_max=1.0,
                              policy=Policy.BEV if spec is None
                              else Policy.EF),
            attack=AttackConfig(attack=AttackType.STRONGEST,
                                byzantine_mask=first_n_mask(u, n_atk)))
        cases.append(ScenarioCase(f"{name}@D{dim}", floa, 0.05, seed=500 + i,
                                  defense=spec if spec is not None
                                  else DefenseSpec()))
    return cases


def bench_lm(series, rounds: int, reps: int) -> dict:
    """D-scaling series (--lm): the big-D regime the real-model LM lanes
    live in, at each D in `series`, unsharded AND ("model",)-sharded over
    every visible device.  The state is a single [D] leaf with a linear
    loss whose per-worker gradient is O(D) to produce, so — mirroring the
    tiny-MLP philosophy of --workers — the rows isolate how the ENGINE
    scales with the flat dimension: the [S, U, D] slab, the standardize
    stats reduction (psum-of-partials when sharded), the OTA combine, the
    columnwise screening sort at D past the kernel-routing thresholds, and
    the TILE_D ghost-column padding.  Real-model wall time (transformer
    fwd/bwd flops) is the LM lane's own business, measured end to end by
    examples/train_floa_lm.py; timing it here would drown the engine ops
    the gate is meant to guard.  Timing reps are capped at 2: the D=1e7
    rows move ~GB slabs per round on a CPU box."""
    u, n_atk = 8, 2
    reps = min(reps, 2)

    def loss(params, b):
        # [D]-state linear probe: grad_w = (mean(w) - t) / D * ones — O(D)
        # per worker with a per-worker batch scalar, no [B, D] features to
        # stage (at D=1e7 a feature matrix would be the benchmark).
        return 0.5 * jnp.mean((jnp.mean(params["w"]) - b["t"]) ** 2)

    shards_m = jax.device_count()
    out = {}
    print(f"# lm d-scaling: D series {list(series)}, U={u}, "
          f"R={rounds} rounds, model_shards={shards_m}")
    print("d,engine,lanes,cold_rounds_per_sec,warm_rounds_per_sec")
    for d in series:
        rng = np.random.default_rng(d % (1 << 31))
        params = {"w": jnp.asarray(rng.normal(size=(d,)).astype(np.float32)
                                   / np.sqrt(d))}
        batches = {"t": rng.normal(size=(rounds, u, 1)).astype(np.float32)}
        spec = SweepSpec.build(lm_grid(u, d, n_atk))
        engines = {"unsharded": SweepEngine(loss, spec)}
        row = dict(d=d, u=u, lanes=len(spec), rounds=rounds,
                   model_shards=shards_m)
        if shards_m > 1:
            engines["model_sharded"] = SweepEngine(
                loss, spec, plan=ExecutionPlan(
                    mesh=make_sweep_mesh(shards_m, model_shards=shards_m)))
        for name, engine in engines.items():
            t0 = time.perf_counter()
            engine.run(params, batches)
            cold = time.perf_counter() - t0
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                engine.run(params, batches)
                best = min(best, time.perf_counter() - t0)
            total = len(spec) * rounds
            row[name] = dict(cold_rounds_per_sec=round(total / cold, 2),
                             warm_rounds_per_sec=round(total / best, 2))
            print(f"{d},{name},{len(spec)},"
                  f"{row[name]['cold_rounds_per_sec']:.1f},"
                  f"{row[name]['warm_rounds_per_sec']:.1f}")
        out[f"D{d}"] = row
    return out


_CACHE_CHILD = r"""
import sys, time
import jax, jax.numpy as jnp, numpy as np
from repro import setup_compilation_cache
setup_compilation_cache(sys.argv[1], min_compile_time_secs=0)
from repro.core import (AttackConfig, AttackType, ChannelConfig, FLOAConfig,
                        PowerConfig, first_n_mask)
from repro.fl import ScenarioCase, SweepEngine, SweepSpec

d_in, d_h = 8, 4
dim = d_in * d_h + d_h

def loss(params, b):
    pred = jax.nn.relu(b["x"] @ params["w1"]) @ params["w2"]
    return jnp.mean((pred - b["y"]) ** 2)

k = jax.random.PRNGKey(0)
params = {"w1": jax.random.normal(k, (d_in, d_h)),
          "w2": jax.random.normal(k, (d_h, 1))}
u, rounds = 4, 4
rng = np.random.default_rng(0)
batches = {"x": rng.normal(size=(rounds, u, d_in)).astype(np.float32),
           "y": rng.normal(size=(rounds, u, 1)).astype(np.float32)}
cases = [ScenarioCase(
    f"lane{i}",
    FLOAConfig(channel=ChannelConfig(num_workers=u, sigma=1.0,
                                     noise_std=0.05),
               power=PowerConfig(num_workers=u, dim=dim, p_max=1.0),
               attack=AttackConfig(
                   attack=AttackType.STRONGEST if i % 2 else AttackType.NONE,
                   byzantine_mask=first_n_mask(u, i % 2))),
    0.05, seed=100 + i) for i in range(4)]
t0 = time.perf_counter()
SweepEngine(loss, SweepSpec.build(cases)).run(params, batches)
print(f"SWEEP_ELAPSED {time.perf_counter() - t0:.4f}")
"""


def bench_resume(mc, shards, params, rounds: int, scenarios: int, reps: int,
                 chunk: int) -> dict:
    """Preemption-safety machinery (--resume): checkpoint tax, resume
    restore, and the persistent-compilation-cache warm restart.

    `chunked` vs `chunked_ckpt` is the same chunked grid with and without
    a checkpoint_dir (every chunk boundary commits the full resume carry
    atomically) — the warm ratio is what preemption safety costs per
    round.  `resume_latest_s` times `run(resume=True)` restoring off the
    last committed boundary and finishing the run: the wall a preempted
    fleet pays to get back to where it died.  The `cache` rows launch two
    fresh subprocesses running an identical tiny sweep against one
    compilation-cache dir — cold populates, warm restarts off the disk
    cache — subprocess wall time, deliberately NOT gated."""
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)
    exps = grid(scenarios, rounds)
    spec = SweepSpec.build([
        ScenarioCase(e.name, floa, alpha, seed=e.seed)
        for e, (floa, alpha) in zip(exps,
                                    [experiment_floa(e, mc) for e in exps])])
    chunk = max(1, min(chunk, rounds))
    total = len(spec) * rounds
    out = dict(lanes=len(spec), rounds=rounds, chunk_rounds=chunk,
               dim=mc.dim)
    print(f"# resume: R={rounds} rounds x S={len(spec)} lanes, "
          f"chunk={chunk}, D={mc.dim}")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        engines = [
            ("chunked", SweepEngine(mlp_loss, spec, plan=ExecutionPlan(
                chunk_rounds=chunk))),
            ("chunked_ckpt", SweepEngine(mlp_loss, spec, plan=ExecutionPlan(
                chunk_rounds=chunk, checkpoint_dir=ckpt_dir))),
        ]
        cold, best = {}, {}
        for name, eng in engines:
            t0 = time.perf_counter()
            eng.run(params, batches)
            cold[name] = time.perf_counter() - t0
            best[name] = float("inf")
        for _ in range(reps):
            for name, eng in engines:
                t0 = time.perf_counter()
                eng.run(params, batches)
                best[name] = min(best[name], time.perf_counter() - t0)
        print("engine,cold_rounds_per_sec,warm_rounds_per_sec")
        for name, _ in engines:
            out[name] = dict(
                cold_rounds_per_sec=round(total / cold[name], 2),
                warm_rounds_per_sec=round(total / best[name], 2))
            print(f"{name},{out[name]['cold_rounds_per_sec']:.1f},"
                  f"{out[name]['warm_rounds_per_sec']:.1f}")
        out["checkpoint_tax"] = round(best["chunked_ckpt"]
                                      / best["chunked"], 3)
        # Resume off the last committed boundary: restore + final chunk(s).
        t0 = time.perf_counter()
        engines[1][1].run(params, batches, resume=True)
        out["resume_latest_s"] = round(time.perf_counter() - t0, 4)
        print(f"# checkpoint tax (warm chunked_ckpt/chunked wall): "
              f"{out['checkpoint_tax']:.2f}x; resume off latest boundary: "
              f"{out['resume_latest_s']:.2f}s")
    # Compilation-cache warm restart: same program, two fresh processes,
    # one persistent cache dir.
    with tempfile.TemporaryDirectory() as cache_dir:
        env = dict(os.environ, REPRO_COMPILATION_CACHE=cache_dir)
        walls = []
        for phase in ("cold", "warm"):
            t0 = time.perf_counter()
            proc = subprocess.run([sys.executable, "-c", _CACHE_CHILD,
                                   cache_dir], env=env, capture_output=True,
                                  text=True, timeout=600)
            walls.append(time.perf_counter() - t0)
            if proc.returncode != 0:
                print(f"# cache {phase} subprocess failed:\n{proc.stderr}")
                out["cache"] = dict(error=f"{phase} subprocess failed")
                return out
        out["cache"] = dict(
            cold_s=round(walls[0], 2), warm_s=round(walls[1], 2),
            warm_restart_speedup=round(walls[0] / walls[1], 3))
        print(f"# compilation cache: cold {out['cache']['cold_s']:.1f}s, "
              f"warm restart {out['cache']['warm_s']:.1f}s "
              f"({out['cache']['warm_restart_speedup']:.2f}x)")
    return out


def check_regressions(fresh: dict, baseline: dict,
                      tolerance: float) -> (list, list):
    """Per-row warm-throughput regression gate (the CI perf check).

    Compares fresh warm rounds/sec against a committed baseline record for
    every engine and defense row present in BOTH; a row fails when

        fresh_warm < baseline_warm * (1 - tolerance)

    (tolerance is generous — CI boxes are shared and the committed baseline
    may come from different hardware; the gate catches structural collapses
    like the grouped dispatch silently falling back to the switch path, not
    single-digit noise).  Rows whose shape parameters differ between the
    records are skipped, not failed.  Returns (failures, notes).
    """
    fails, notes = [], []

    def gate(section, name, f_row, b_row):
        f_w, b_w = f_row["warm_rounds_per_sec"], b_row["warm_rounds_per_sec"]
        floor = b_w * (1.0 - tolerance)
        if f_w < floor:
            fails.append(f"{section}/{name}: warm {f_w:.1f} r/s < floor "
                         f"{floor:.1f} (baseline {b_w:.1f}, "
                         f"tolerance {tolerance})")

    if all(fresh.get(k) == baseline.get(k) for k in ("scenarios", "rounds")):
        chunk_mismatch = (fresh.get("chunk_rounds")
                          != baseline.get("chunk_rounds"))
        for name, b_row in (baseline.get("engines") or {}).items():
            f_row = (fresh.get("engines") or {}).get(name)
            if f_row is None:
                notes.append(f"engines/{name}: not in fresh run, skipped")
            elif "chunk" in name and chunk_mismatch:
                # A different chunk size is a different program shape (e.g.
                # --chunk-rounds 1 is per-chunk dispatch overhead x R); like
                # the defense rows' lanes/rounds guard, skip rather than
                # report a phantom regression.
                notes.append(f"engines/{name}: chunk_rounds differs from "
                             "baseline, skipped")
            else:
                gate("engines", name, f_row, b_row)
    else:
        notes.append("engine rows skipped: scenarios/rounds differ from "
                     "baseline")
    for name, b_row in (baseline.get("defenses") or {}).items():
        f_row = (fresh.get("defenses") or {}).get(name)
        if f_row is None:
            notes.append(f"defenses/{name}: not in fresh run, skipped")
        elif (f_row.get("lanes"), f_row.get("rounds")) != (
                b_row.get("lanes"), b_row.get("rounds")):
            notes.append(f"defenses/{name}: lane/round shape differs, skipped")
        else:
            gate("defenses", name, f_row, b_row)
    for name, b_row in (baseline.get("scenario_axes") or {}).items():
        f_row = (fresh.get("scenario_axes") or {}).get(name)
        if f_row is None:
            notes.append(f"scenario_axes/{name}: not in fresh run, skipped")
        elif (f_row.get("lanes"), f_row.get("rounds")) != (
                b_row.get("lanes"), b_row.get("rounds")):
            notes.append(f"scenario_axes/{name}: lane/round shape differs, "
                         "skipped")
        else:
            gate("scenario_axes", name, f_row, b_row)
    b_res = baseline.get("resume")
    if b_res:
        f_res = fresh.get("resume")
        if f_res is None:
            notes.append("resume: not in fresh run, skipped")
        elif any(f_res.get(k) != b_res.get(k)
                 for k in ("lanes", "rounds", "chunk_rounds", "dim")):
            # A different grid/chunk shape is a different program — skip,
            # don't fail (mirrors the workers-series guard).
            notes.append("resume: lanes/rounds/chunk shape differs, skipped")
        else:
            for sub in ("chunked", "chunked_ckpt"):
                if sub in b_res and sub in f_res:
                    gate("resume", sub, f_res[sub], b_res[sub])
            # The subprocess cache timings are machine-noise bound and
            # never gated.
    for name, b_row in (baseline.get("workers") or {}).items():
        f_row = (fresh.get("workers") or {}).get(name)
        if f_row is None:
            notes.append(f"workers/{name}: not in fresh run, skipped")
        elif any(f_row.get(k) != b_row.get(k)
                 for k in ("u", "lanes", "rounds", "dim", "worker_shards")):
            # A different U series / device count is a different program
            # shape (e.g. CI's reduced --workers-series, or a sharded row
            # timed at another worker_shards) — skip, don't fail.
            notes.append(f"workers/{name}: U-series shape differs, skipped")
        else:
            for sub in ("unsharded", "sharded"):
                if sub in b_row:
                    if sub not in f_row:
                        notes.append(f"workers/{name}/{sub}: not in fresh "
                                     "run, skipped")
                    else:
                        gate(f"workers/{name}", sub, f_row[sub], b_row[sub])
    for name, b_row in (baseline.get("lm") or {}).items():
        f_row = (fresh.get("lm") or {}).get(name)
        if f_row is None:
            notes.append(f"lm/{name}: not in fresh run, skipped")
        elif any(f_row.get(k) != b_row.get(k)
                 for k in ("d", "u", "lanes", "rounds", "model_shards")):
            # A different D series / device count is a different program
            # shape (mirrors the workers guard).
            notes.append(f"lm/{name}: D-series shape differs, skipped")
        else:
            for sub in ("unsharded", "model_sharded"):
                if sub in b_row:
                    if sub not in f_row:
                        notes.append(f"lm/{name}/{sub}: not in fresh run, "
                                     "skipped")
                    else:
                        gate(f"lm/{name}", sub, f_row[sub], b_row[sub])
    return fails, notes


def grid(num: int, rounds: int):
    """CI/BEV x attacker-count grid, fig-4 style, cycled to `num` lanes."""
    cells = [(pol, n) for n in (0, 1, 2, 3, 4)
             for pol in (Policy.CI, Policy.BEV)]
    return [Experiment(name=f"{cells[i % len(cells)][0].value}"
                            f"@N{cells[i % len(cells)][1]}#{i}",
                       policy=cells[i % len(cells)][0],
                       n_attackers=cells[i % len(cells)][1],
                       alpha_hat=0.1, rounds=rounds, seed=100 + i)
            for i in range(num)]


def main(rounds: int = 25, scenarios: int = 16, sharded: bool = False,
         reps: int = 3, skip_looped: bool = False, defenses: bool = False,
         defense_rounds: int = 10, defense_scenarios: int = 6,
         chunk_rounds: int = 5, scenario_axes: bool = False,
         scenario_rounds: int = 10, scenario_lanes: int = 8,
         workers: bool = False,
         workers_series: str = "10,1000,10000", workers_rounds: int = 3,
         lm: bool = False, lm_series: str = "50000,1000000,10000000",
         lm_rounds: int = 3,
         resume: bool = False, resume_rounds: int = 10,
         resume_lanes: int = 8,
         out_path: str = "BENCH_sweep.json",
         check_against: str = "", tolerance: float = 0.5) -> dict:
    base_record = None
    if check_against:
        # Load BEFORE running: --out may point at the same file (the CI job
        # regenerates the committed BENCH_sweep.json it gates against).
        with open(check_against) as f:
            base_record = json.load(f)
    mc, shards, params, _ = figure_setup()
    exps = grid(scenarios, rounds)
    cfgs = [experiment_floa(e, mc) for e in exps]
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)

    class Replay:
        """Feed the looped trainer the same pre-staged batches the scan
        engines consume, so the timers isolate engine overhead rather than
        charging host-side numpy sampling to the looped path only."""

        def __init__(self):
            self.t = 0

        def next_round(self):
            out = {k: v[self.t % rounds] for k, v in batches.items()}
            self.t += 1
            return out

    total = len(exps) * rounds
    cold, warm = {}, {}
    runners = []  # (name, run_once); cold-timed on registration

    def measure(name, run_once):
        t0 = time.perf_counter()
        run_once()
        cold[name] = time.perf_counter() - t0
        runners.append((name, run_once))

    def run_looped(trainers):
        for tr, e in zip(trainers, exps):
            p, _ = tr.run(params, Replay(), rounds,
                          jax.random.PRNGKey(e.seed), eval_every=0)
            jax.block_until_ready(p)

    def run_scans(trainers):
        for tr, e in zip(trainers, exps):
            # run_scan syncs internally (round losses come back as np arrays)
            tr.run_scan(params, batches, jax.random.PRNGKey(e.seed),
                        eval_every=0)

    # --- looped: fresh trainers => one compile per scenario, then per-round
    # dispatch; warm rerun reuses the compiled round_steps.
    if not skip_looped:
        trainers = [FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha)
                    for floa, alpha in cfgs]
        measure("looped", lambda t=trainers: run_looped(t))

        # --- scan: one lax.scan program (compile) per scenario.
        trainers = [FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha)
                    for floa, alpha in cfgs]
        measure("scan", lambda t=trainers: run_scans(t))

    spec = SweepSpec.build([
        ScenarioCase(e.name, floa, alpha, seed=e.seed)
        for e, (floa, alpha) in zip(exps, cfgs)
    ])

    # --- scan+vmap: the PR 1 tree-state engine — whole grid, one program.
    engine = SweepEngine(mlp_loss, spec, plan=ExecutionPlan(flat_state=False))
    measure("scan+vmap", lambda e=engine: e.run(params, batches))

    # --- flat: flat-state scan + fused combine/update (this PR's warm path).
    engine = SweepEngine(mlp_loss, spec)
    measure("flat", lambda e=engine: e.run(params, batches))

    # --- flat+chunk(+async): scan-of-chunks execution, with and without the
    # double-buffered host->device staging — the A/B isolates the input-
    # pipeline overlap from the chunking itself.
    chunk = max(1, min(chunk_rounds, rounds))
    engine = SweepEngine(mlp_loss, spec,
                         plan=ExecutionPlan(chunk_rounds=chunk))
    measure("flat+chunk", lambda e=engine: e.run(params, batches))
    engine = SweepEngine(mlp_loss, spec, plan=ExecutionPlan(
        chunk_rounds=chunk, async_staging=True))
    measure("flat+chunk+async", lambda e=engine: e.run(params, batches))

    # --- flat+shmap: the same flat scan sharded over every visible device.
    if sharded:
        engine = SweepEngine(mlp_loss, spec,
                             plan=ExecutionPlan(mesh=make_sweep_mesh()))
        measure("flat+shmap", lambda e=engine: e.run(params, batches))

    # Warm reps are interleaved across engines (A B C A B C ...) and each
    # engine keeps its best: on shared/noisy boxes consecutive reps alias
    # the machine's slow phases onto whichever engine happens to be running,
    # while interleaving spreads them evenly.
    best = {name: float("inf") for name, _ in runners}
    for _ in range(reps):
        for name, run_once in runners:
            t0 = time.perf_counter()
            run_once()
            best[name] = min(best[name], time.perf_counter() - t0)
    warm.update(best)

    print(f"# paper MLP (D={mc.dim}), S={len(exps)} scenarios x R={rounds} "
          f"rounds, backend={jax.default_backend()}, "
          f"devices={jax.device_count()}")
    print("engine,cold_rounds_per_sec,warm_rounds_per_sec,"
          "cold_speedup_vs_baseline,warm_speedup_vs_baseline")
    baseline = "looped" if "looped" in cold else "scan+vmap"
    engines = {}
    for name in cold:
        c, w = total / cold[name], total / warm[name]
        engines[name] = dict(
            cold_rounds_per_sec=round(c, 2), warm_rounds_per_sec=round(w, 2),
            cold_speedup=round(cold[baseline] / cold[name], 3),
            warm_speedup=round(warm[baseline] / warm[name], 3))
        print(f"{name},{c:.1f},{w:.1f},"
              f"{engines[name]['cold_speedup']:.2f}x,"
              f"{engines[name]['warm_speedup']:.2f}x")

    record = dict(
        bench="sweep", scenarios=len(exps), rounds=rounds, dim=mc.dim,
        num_workers=mc.num_workers, backend=jax.default_backend(),
        devices=jax.device_count(), baseline=baseline, reps=reps,
        chunk_rounds=chunk, engines=engines,
    )
    if "scan+vmap" in engines and "flat" in engines:
        record["flat_vs_pr1_warm_speedup"] = round(
            warm["scan+vmap"] / warm["flat"], 3)
        if "flat+shmap" in engines:
            record["sharded_vs_pr1_warm_speedup"] = round(
                warm["scan+vmap"] / warm["flat+shmap"], 3)
    if "flat+chunk" in engines and "flat+chunk+async" in engines:
        # The input-pipeline A/B: >1 means the double-buffered staging won
        # warm wall time over synchronous per-chunk staging.
        record["async_staging_warm_speedup"] = round(
            warm["flat+chunk"] / warm["flat+chunk+async"], 3)
    if defenses:
        record["defenses"] = bench_defenses(
            mc, shards, params, defense_rounds, defense_scenarios, reps)
        d = record["defenses"]
        if "mixed" in d and "mixed_switch" in d:
            # The tentpole number: grouped dispatch vs the per-lane switch
            # on the mixed all-families grid.
            record["mixed_grouped_vs_switch_warm_speedup"] = round(
                d["mixed"]["warm_rounds_per_sec"]
                / d["mixed_switch"]["warm_rounds_per_sec"], 3)
            print(f"# mixed grid grouped vs switch warm speedup: "
                  f"{record['mixed_grouped_vs_switch_warm_speedup']:.2f}x")
    if scenario_axes:
        record["scenario_axes"] = bench_scenario_axes(
            mc, shards, params, scenario_rounds, scenario_lanes, reps)
    if workers:
        series = [int(s) for s in str(workers_series).split(",") if s]
        record["workers"] = bench_workers(series, workers_rounds, reps)
    if lm:
        series = [int(s) for s in str(lm_series).split(",") if s]
        record["lm"] = bench_lm(series, lm_rounds, reps)
    if resume:
        # The raw --chunk-rounds, re-clamped against the resume grid's own
        # rounds (the headline clamp above used the headline rounds).
        record["resume"] = bench_resume(
            mc, shards, params, resume_rounds, resume_lanes, reps,
            chunk_rounds)
    # Gate BEFORE writing --out so the persisted record (the CI artifact)
    # carries the regression verdict, not just the raw numbers.
    if base_record is not None:
        fails, notes = check_regressions(record, base_record, tolerance)
        for n in notes:
            print(f"# check: {n}")
        if fails:
            print(f"# PERF REGRESSION vs {check_against} "
                  f"(tolerance {tolerance}):")
            for msg in fails:
                print(f"#   {msg}")
            record["regressions"] = fails
        else:
            print(f"# perf check vs {check_against}: OK "
                  f"(tolerance {tolerance})")
    if out_path:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {out_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--scenarios", type=int, default=16)
    ap.add_argument("--sharded", action="store_true",
                    help="also bench SweepEngine(mesh=...) over all devices "
                         "(pair with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 on CPU)")
    ap.add_argument("--reps", type=int, default=3,
                    help="warm reruns per engine (best-of, for noisy boxes)")
    ap.add_argument("--skip-looped", action="store_true",
                    help="skip the per-scenario looped/scan baselines")
    ap.add_argument("--defenses", action="store_true",
                    help="also bench the defense-code lane axis (one engine "
                         "per defense family + the mixed grid)")
    ap.add_argument("--defense-rounds", type=int, default=10,
                    help="rounds per defense-family engine (--defenses)")
    ap.add_argument("--defense-scenarios", type=int, default=6,
                    help="lanes per defense-family engine (--defenses)")
    ap.add_argument("--chunk-rounds", type=int, default=5,
                    help="chunk size C for the flat+chunk(+async) rows "
                         "(clamped to [1, rounds])")
    ap.add_argument("--scenario-axes", action="store_true",
                    help="also bench the adaptive-adversary lane axes "
                         "(legacy / markov / participation / directional / "
                         "mixed_axes, one engine per axis)")
    ap.add_argument("--scenario-rounds", type=int, default=10,
                    help="rounds per scenario-axis engine (--scenario-axes)")
    ap.add_argument("--scenario-lanes", type=int, default=8,
                    help="lanes per scenario-axis engine (--scenario-axes)")
    ap.add_argument("--workers", action="store_true",
                    help="also bench the worker-population scaling series "
                         "(mixed-defense grid at each U, unsharded + "
                         "worker-sharded over every visible device)")
    ap.add_argument("--workers-series", default="10,1000,10000",
                    help="comma-separated U values for --workers")
    ap.add_argument("--workers-rounds", type=int, default=3,
                    help="rounds per worker-scaling engine (--workers)")
    ap.add_argument("--lm", action="store_true",
                    help="also bench the flat-dimension scaling series "
                         "(mixed analog/median grid at each D, unsharded + "
                         "model-sharded over every visible device — the "
                         "big-D regime of the real-model LM lanes)")
    ap.add_argument("--lm-series", default="50000,1000000,10000000",
                    help="comma-separated D values for --lm")
    ap.add_argument("--lm-rounds", type=int, default=3,
                    help="rounds per D-scaling engine (--lm)")
    ap.add_argument("--resume", action="store_true",
                    help="also bench the preemption-safety machinery: "
                         "checkpointed-chunked vs plain-chunked warm "
                         "throughput, resume-restore wall, and the "
                         "compilation-cache cold/warm subprocess restart")
    ap.add_argument("--resume-rounds", type=int, default=10,
                    help="rounds for the --resume checkpoint A/B grid")
    ap.add_argument("--resume-lanes", type=int, default=8,
                    help="lanes for the --resume checkpoint A/B grid")
    ap.add_argument("--out", default="BENCH_sweep.json",
                    help="machine-readable output path ('' to disable)")
    ap.add_argument("--check-against", default="",
                    help="baseline BENCH_sweep.json to gate against: exits "
                         "non-zero if any engine/defense row's fresh warm "
                         "rounds/sec falls below baseline * (1 - tolerance)")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional warm-throughput drop vs the "
                         "--check-against baseline (generous by default: "
                         "shared CI runners are noisy)")
    args = ap.parse_args()
    rec = main(rounds=args.rounds, scenarios=args.scenarios,
               sharded=args.sharded, reps=args.reps,
               skip_looped=args.skip_looped, defenses=args.defenses,
               defense_rounds=args.defense_rounds,
               defense_scenarios=args.defense_scenarios,
               chunk_rounds=args.chunk_rounds,
               scenario_axes=args.scenario_axes,
               scenario_rounds=args.scenario_rounds,
               scenario_lanes=args.scenario_lanes, workers=args.workers,
               workers_series=args.workers_series,
               workers_rounds=args.workers_rounds, lm=args.lm,
               lm_series=args.lm_series, lm_rounds=args.lm_rounds,
               resume=args.resume,
               resume_rounds=args.resume_rounds,
               resume_lanes=args.resume_lanes, out_path=args.out,
               check_against=args.check_against, tolerance=args.tolerance)
    if rec.get("regressions"):
        raise SystemExit(1)
