"""Digital-defense comparison (beyond paper): the screening aggregators the
paper argues are incompatible with analog aggregation, run in digital mode
against the same attack, next to FLOA-BEV.  Quantifies the robustness the
analog scheme gives up vs the per-worker-gradient communication it saves.

CSV: fig,experiment,round,loss,accuracy
"""
from __future__ import annotations

import jax

from benchmarks.common import (
    Experiment, Policy, print_csv, run_experiment,
)
from benchmarks import common as C
from repro.fl import FLTrainer
from repro.core import AttackType
import jax.numpy as jnp

from repro.configs.registry import PAPER_MLP
from repro.core import (AttackConfig, ChannelConfig, FLOAConfig, PowerConfig,
                        first_n_mask, noise_std_for_snr)
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss


def run_digital(defense: str, n_attackers: int, rounds: int = 150, **dkw):
    mc = PAPER_MLP.full()
    u, d = mc.num_workers, mc.dim
    floa = FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=0.0),
        power=PowerConfig(num_workers=u, dim=d, p_max=1.0,
                          policy=C.Policy.EF),
        attack=AttackConfig(attack=AttackType.STRONGEST,
                            byzantine_mask=first_n_mask(u, n_attackers)),
    )
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    tr = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=0.1, mode="digital",
                   defense=defense, defense_kwargs=dkw,
                   eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt_j, yt_j)})
    sampler = FederatedSampler(worker_split(x, y, u), mc.batch_per_worker, seed=1)
    _, logs = tr.run(init_mlp(jax.random.PRNGKey(0)), sampler, rounds,
                     jax.random.PRNGKey(7), eval_every=10)
    return logs


def main(rounds: int = 120) -> None:
    n = 3
    exp = Experiment(name=f"FLOA-BEV@N{n}", policy=Policy.BEV, n_attackers=n,
                     alpha_hat=0.1, rounds=rounds)
    print_csv("defenses", exp, run_experiment(exp))
    for defense, kw in [("mean", {}), ("median", {}),
                        ("trimmed_mean", dict(trim=3)),
                        ("krum", dict(num_byzantine=3)),
                        ("geometric_median", {})]:
        logs = run_digital(defense, n, rounds=rounds, **kw)
        for lg in logs:
            print(f"defenses,digital-{defense}@N{n},{lg.step},"
                  f"{lg.loss:.5f},{lg.accuracy:.4f}")


if __name__ == "__main__":
    main()
