"""Digital-defense comparison (beyond paper): the screening aggregators the
paper argues are incompatible with analog aggregation, run in digital mode
against the same attack, next to FLOA-BEV.  Quantifies the robustness the
analog scheme gives up vs the per-worker-gradient communication it saves.

Execution: every row — the analog FLOA-BEV lane AND each digital defense —
is one lane of a single compiled sweep (the defense-code lane axis), so the
whole comparison is one XLA program.  Dispatch is grouped by default (each
defense family's kernel runs once over its own contiguous lane group);
--dispatch switch keeps the per-lane vmapped lax.switch reference, which
computes every family for every lane — useful for eyeballing the wall-time
difference on this exact grid.

CSV: fig,experiment,round,loss,accuracy
"""
from __future__ import annotations

import argparse

from benchmarks.common import Experiment, Policy, experiment_floa, figure_setup
from benchmarks.render_tables import print_sweep_csv
from repro.core import (AttackConfig, AttackType, ChannelConfig, DefenseSpec,
                        FLOAConfig, PowerConfig, first_n_mask)
from repro.data import FederatedSampler
from repro.fl import ExecutionPlan, ScenarioCase, SweepEngine, SweepSpec
from repro.models import mlp_loss

DEFENSES = [
    ("mean", DefenseSpec(name="mean")),
    ("median", DefenseSpec(name="median")),
    ("trimmed_mean", DefenseSpec(name="trimmed_mean", trim=3)),
    ("krum", DefenseSpec(name="krum", num_byzantine=3)),
    ("geometric_median", DefenseSpec(name="geometric_median")),
]


def main(rounds: int = 120, eval_every: int = 10,
         dispatch: str = "grouped") -> None:
    n = 3
    mc, shards, params, eval_fn = figure_setup()
    u, d = mc.num_workers, mc.dim

    exp = Experiment(name=f"FLOA-BEV@N{n}", policy=Policy.BEV, n_attackers=n,
                     alpha_hat=0.1, rounds=rounds)
    cases = [ScenarioCase(exp.name, *experiment_floa(exp, mc), seed=exp.seed)]
    digital_floa = FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=0.0),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max,
                          policy=Policy.EF),
        attack=AttackConfig(attack=AttackType.STRONGEST,
                            byzantine_mask=first_n_mask(u, n)))
    for name, spec in DEFENSES:
        cases.append(ScenarioCase(f"digital-{name}@N{n}", digital_floa, 0.1,
                                  seed=7, defense=spec))

    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)
    result = SweepEngine(
        mlp_loss, SweepSpec.build(cases), eval_fn=eval_fn,
        eval_every=eval_every, plan=ExecutionPlan(
            grouped_dispatch=(dispatch == "grouped"))).run(params, batches)
    print_sweep_csv("defenses", result, eval_every)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--dispatch", choices=("grouped", "switch"),
                    default="grouped",
                    help="defense-lane dispatch: static grouped partition "
                         "(default) or the per-lane lax.switch reference")
    args = ap.parse_args()
    main(rounds=args.rounds, eval_every=args.eval_every,
         dispatch=args.dispatch)
