"""§Roofline report: three-term roofline per (arch x shape) from dry-run JSON.

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and prints the
table EXPERIMENTS.md §Roofline embeds: compute/memory/collective seconds per
step, dominant term, MODEL_FLOPS, useful-compute ratio.
CSV: arch,shape,mesh,compute_s,memory_s,collective_s,dominant,model_flops,
     useful_ratio,hbm_gb_per_device
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def main(dirpath: str = "results/dryrun") -> None:
    recs = load(dirpath)
    if not recs:
        print(f"# no dry-run records in {dirpath}; run "
              f"`python -m repro.launch.dryrun --all` first")
        return
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "model_flops,useful_ratio,hbm_gb_per_device,status")
    for r in recs:
        if r.get("status") != "ok":
            print(f"{r['arch']},{r['shape']},{r['mesh']},,,,,,,,{r['status']}")
            continue
        t = r["roofline"]
        mem = r.get("memory") or {}
        hbm = sum(v for v in (mem.get("argument_size"), mem.get("temp_size"),
                              mem.get("output_size")) if v) / 1e9
        ur = r.get("useful_ratio")
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{t['compute_s']:.4g},{t['memory_s']:.4g},"
              f"{t['collective_s']:.4g},{r['dominant']},"
              f"{r['model_flops']:.4g},{ur if ur is None else round(ur, 3)},"
              f"{hbm:.2f},ok")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
