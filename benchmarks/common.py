"""Shared harness for the paper-figure benchmarks (Figs 1-4, §IV).

Experimental setup per the paper: MLP 784-64-10 (D=50890), U=10 workers,
3000 training samples i.i.d.-split, receive SNR 10 dB, Rayleigh CN(0,1)
channels, strongest attack (Thm 1), learning rate set via the scaled
alpha_hat = (Omega/omega) * alpha.

Each figure is ONE compiled sweep (`run_figure`): every experiment becomes a
lane of a stacked scenario axis and all rounds run inside one scan — no
per-round or per-experiment Python dispatch.  `run_experiment` keeps the
legacy looped-trainer path for comparison (see sweep_bench.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import PAPER_MLP
from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
    noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import (ExecutionPlan, FLTrainer, ScenarioCase, SweepEngine,
                      SweepSpec)
from repro.models import init_mlp, mlp_accuracy, mlp_loss

jax.config.update("jax_threefry_partitionable", True)


@dataclasses.dataclass
class Experiment:
    name: str
    policy: Policy
    n_attackers: int = 0
    alpha_hat: float = 0.1
    attack: AttackType = AttackType.STRONGEST
    attacker_sigma: Optional[float] = None  # None = same as honest (1.0)
    rounds: int = 150
    seed: int = 42


def experiment_floa(exp: Experiment, mc=None) -> Tuple[FLOAConfig, float]:
    """Experiment -> (FLOAConfig, raw alpha) — the paper's §IV setup."""
    mc = mc or PAPER_MLP.full()
    u, d = mc.num_workers, mc.dim
    sigma = [exp.attacker_sigma if (exp.attacker_sigma is not None and
                                    i < exp.n_attackers) else mc.sigma
             for i in range(u)]
    tp = theory.TheoryParams(num_workers=u, num_attackers=exp.n_attackers,
                             dim=d, sigma=tuple(sigma), p_max=mc.p_max)
    pol = "ef" if exp.policy == Policy.EF else exp.policy.value
    alpha = theory.alpha_from_alpha_hat(tp, pol, exp.alpha_hat)

    zstd = (0.0 if exp.policy == Policy.EF
            else noise_std_for_snr(mc.p_max, d, mc.snr_db))
    floa = FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=tuple(sigma),
                              noise_std=zstd),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max,
                          policy=exp.policy),
        attack=AttackConfig(
            attack=exp.attack if exp.n_attackers else AttackType.NONE,
            byzantine_mask=first_n_mask(u, exp.n_attackers)),
    )
    return floa, alpha


def figure_setup(mc=None):
    """Dataset + init + eval shared by every figure (and every lane)."""
    mc = mc or PAPER_MLP.full()
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    shards = worker_split(x, y, mc.num_workers)
    params = init_mlp(jax.random.PRNGKey(0))
    eval_fn = lambda p: {"accuracy": mlp_accuracy(p, xt_j, yt_j)}
    return mc, shards, params, eval_fn


def run_figure(exps: List[Experiment], eval_every: int = 10,
               mc=None, mesh=None):
    """All of a figure's experiments as ONE compiled sweep call.

    Every experiment uses the same dataset and batch sequence (sampler
    seed=1), exactly as the legacy per-experiment loop did.  Returns the
    `SweepResult` itself — the figure scripts hand it straight to
    `render_tables.print_sweep_csv` / `sweep_markdown` (no per-experiment
    CSV intermediates); `result.logs(name, eval_every)` recovers the legacy
    RoundLog lists.  Pass mesh= (e.g. `launch.mesh.make_sweep_mesh()`) to
    shard the scenario lanes over devices.
    """
    mc, shards, params, eval_fn = figure_setup(mc)
    rounds = exps[0].rounds
    assert all(e.rounds == rounds for e in exps), "one sweep, one R"
    spec = SweepSpec.build([
        ScenarioCase(e.name, *experiment_floa(e, mc), seed=e.seed)
        for e in exps
    ])
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(rounds)
    return SweepEngine(
        mlp_loss, spec, eval_fn=eval_fn, eval_every=eval_every,
        plan=ExecutionPlan(mesh=mesh)).run(params, batches)


def run_experiment(exp: Experiment, eval_every: int = 10) -> List:
    """Legacy path: one experiment through the looped FLTrainer (kept as the
    sweep engine's ground truth and as sweep_bench's baseline)."""
    mc, shards, params, eval_fn = figure_setup()
    floa, alpha = experiment_floa(exp, mc)
    tr = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha, eval_fn=eval_fn)
    sampler = FederatedSampler(shards, batch_per_worker=mc.batch_per_worker,
                               seed=1)
    _, logs = tr.run(params, sampler, exp.rounds, jax.random.PRNGKey(exp.seed),
                     eval_every=eval_every)
    return logs


def print_csv(tag: str, exp_or_name, logs: List) -> None:
    name = exp_or_name if isinstance(exp_or_name, str) else exp_or_name.name
    for lg in logs:
        print(f"{tag},{name},{lg.step},{lg.loss:.5f},{lg.accuracy:.4f}")
