"""Shared harness for the paper-figure benchmarks (Figs 1-4, §IV).

Experimental setup per the paper: MLP 784-64-10 (D=50890), U=10 workers,
3000 training samples i.i.d.-split, receive SNR 10 dB, Rayleigh CN(0,1)
channels, strongest attack (Thm 1), learning rate set via the scaled
alpha_hat = (Omega/omega) * alpha.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import PAPER_MLP
from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
    noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import FLTrainer
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

jax.config.update("jax_threefry_partitionable", True)


@dataclasses.dataclass
class Experiment:
    name: str
    policy: Policy
    n_attackers: int = 0
    alpha_hat: float = 0.1
    attack: AttackType = AttackType.STRONGEST
    attacker_sigma: Optional[float] = None  # None = same as honest (1.0)
    rounds: int = 150
    seed: int = 42


def run_experiment(exp: Experiment, eval_every: int = 10) -> List:
    mc = PAPER_MLP.full()
    u, d = mc.num_workers, mc.dim
    sigma = [exp.attacker_sigma if (exp.attacker_sigma is not None and
                                    i < exp.n_attackers) else mc.sigma
             for i in range(u)]
    tp = theory.TheoryParams(num_workers=u, num_attackers=exp.n_attackers,
                             dim=d, sigma=tuple(sigma), p_max=mc.p_max)
    pol = "ef" if exp.policy == Policy.EF else exp.policy.value
    alpha = theory.alpha_from_alpha_hat(tp, pol, exp.alpha_hat)

    zstd = (0.0 if exp.policy == Policy.EF
            else noise_std_for_snr(mc.p_max, d, mc.snr_db))
    floa = FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=tuple(sigma),
                              noise_std=zstd),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max,
                          policy=exp.policy),
        attack=AttackConfig(
            attack=exp.attack if exp.n_attackers else AttackType.NONE,
            byzantine_mask=first_n_mask(u, exp.n_attackers)),
    )

    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    shards = worker_split(x, y, u)
    params = init_mlp(jax.random.PRNGKey(0))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    tr = FLTrainer(
        loss_fn=mlp_loss, floa=floa, alpha=alpha,
        eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt_j, yt_j)},
    )
    sampler = FederatedSampler(shards, batch_per_worker=mc.batch_per_worker,
                               seed=1)
    _, logs = tr.run(params, sampler, exp.rounds, jax.random.PRNGKey(exp.seed),
                     eval_every=eval_every)
    return logs


def print_csv(tag: str, exp: Experiment, logs: List) -> None:
    for lg in logs:
        print(f"{tag},{exp.name},{lg.step},{lg.loss:.5f},{lg.accuracy:.4f}")
