"""§Perf hillclimb harness: measure named (arch x shape x variant) combos with
the same probe-extrapolated roofline methodology as the dry-run, so
before/after deltas are apples-to-apples.

  PYTHONPATH=src python -m benchmarks.perf_iterations deepseek_moe
  PYTHONPATH=src python -m benchmarks.perf_iterations qwen_kv
  PYTHONPATH=src python -m benchmarks.perf_iterations llava_prefill

Each experiment prints CSV: experiment,variant,compute_s,memory_s,
collective_s,dominant,temp_gb and appends a JSON record under
results/perf/ for EXPERIMENTS.md §Perf.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import dataclasses
import json
import sys

import jax

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import hlo_analysis as HA
from repro.launch.dryrun import probe_costs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step


def measure(cfg, shape_name, label, experiment):
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    art = make_step(cfg, mesh, shape_name, shape)
    with mesh:
        compiled = jax.jit(art.fn, in_shardings=art.in_shardings).lower(
            *art.args).compile()
    mem = compiled.memory_analysis()
    flops, nbytes, coll = probe_costs(cfg, mesh, shape_name, shape)
    terms = HA.roofline_terms(flops, nbytes, coll["total"])
    temp_gb = (mem.temp_size_in_bytes or 0) / 1e9
    rec = dict(experiment=experiment, variant=label, shape=shape_name,
               arch=cfg.name, roofline=terms, dominant=HA.dominant(terms),
               flops_per_device=flops, bytes_per_device=nbytes,
               collectives=coll, temp_gb=temp_gb)
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{experiment}__{label}.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{experiment},{label},{terms['compute_s']:.4g},"
          f"{terms['memory_s']:.4g},{terms['collective_s']:.4g},"
          f"{HA.dominant(terms)},{temp_gb:.1f}", flush=True)
    return rec


def _reuse_dryrun_baseline(arch, shape_name, experiment, label):
    """The sweep already measured the baseline with identical methodology."""
    p = f"results/dryrun/{arch}__{shape_name}__single.json"
    if not os.path.exists(p):
        return False
    with open(p) as f:
        d = json.load(f)
    t = d["roofline"]
    rec = dict(experiment=experiment, variant=label, shape=shape_name,
               arch=arch, roofline=t, dominant=d["dominant"],
               flops_per_device=d["flops_per_device"],
               bytes_per_device=d["bytes_per_device"],
               collectives=d["collectives"],
               temp_gb=(d["memory"]["temp_size"] or 0) / 1e9)
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{experiment}__{label}.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{experiment},{label},{t['compute_s']:.4g},{t['memory_s']:.4g},"
          f"{t['collective_s']:.4g},{d['dominant']},{rec['temp_gb']:.1f}",
          flush=True)
    return True


def deepseek_moe() -> None:
    """Hillclimb 1 (compute term): dense-masked MoE -> capacity-gather."""
    base = get_config("deepseek-v2-236b")
    if not _reuse_dryrun_baseline("deepseek-v2-236b", "train_4k",
                                  "deepseek_moe", "baseline_scan_dense"):
        measure(base, "train_4k", "baseline_scan_dense", "deepseek_moe")
    opt = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, impl="capacity_gather"))
    measure(opt, "train_4k", "opt_capacity_gather", "deepseek_moe")


def qwen_kv() -> None:
    """Hillclimb (memory term): int8 KV cache for decode_32k."""
    base = get_config("qwen3-4b")
    measure(base, "decode_32k", "baseline_bf16_cache", "qwen_kv")
    opt = dataclasses.replace(base, kv_cache_dtype="int8")
    measure(opt, "decode_32k", "opt_int8_cache", "qwen_kv")


def llava_prefill() -> None:
    """Hillclimb (collective term): activation-sharding layout for prefill."""
    base = get_config("llava-next-mistral-7b")
    measure(base, "prefill_32k", "baseline_seqshard", "llava_prefill")
    # variant wired via env consumed by launch.sharding (see make_constrain)
    os.environ["REPRO_PREFILL_CONSTRAIN"] = "batch_only"
    try:
        measure(base, "prefill_32k", "opt_batch_only_residuals",
                "llava_prefill")
    finally:
        os.environ.pop("REPRO_PREFILL_CONSTRAIN", None)


EXPERIMENTS = dict(deepseek_moe=deepseek_moe, qwen_kv=qwen_kv,
                   llava_prefill=llava_prefill)


def main() -> None:
    names = sys.argv[1:] or list(EXPERIMENTS)
    print("experiment,variant,compute_s,memory_s,collective_s,dominant,temp_gb")
    for n in names:
        EXPERIMENTS[n]()


if __name__ == "__main__":
    main()
