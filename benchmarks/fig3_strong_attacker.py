"""Fig. 3 — single STRONG attacker (highest channel gain).

Paper claims (§IV-C): omega_CI > 0 is hard to guarantee -> CI cannot converge
(or converges to failure); BEV still converges; larger alpha_hat converges
faster (under the guarantee).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_experiment

STRONG_SIGMA = 3.0  # attacker channel scale >> honest sigma=1.0


def main(rounds: int = 150) -> dict:
    out = {}
    for ah in (0.1, 1.0):
        for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]:
            exp = Experiment(name=f"{name}@ah{ah}", policy=pol, n_attackers=1,
                             alpha_hat=ah, attacker_sigma=STRONG_SIGMA,
                             rounds=rounds)
            logs = run_experiment(exp)
            print_csv("fig3", exp, logs)
            out[exp.name] = logs
    return out


if __name__ == "__main__":
    main()
