"""Fig. 3 — single STRONG attacker (highest channel gain).

Paper claims (§IV-C): omega_CI > 0 is hard to guarantee -> CI cannot converge
(or converges to failure); BEV still converges; larger alpha_hat converges
faster (under the guarantee).
All four setups run as one compiled sweep (4 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_figure

STRONG_SIGMA = 3.0  # attacker channel scale >> honest sigma=1.0


def main(rounds: int = 150) -> dict:
    exps = [Experiment(name=f"{name}@ah{ah}", policy=pol, n_attackers=1,
                       alpha_hat=ah, attacker_sigma=STRONG_SIGMA,
                       rounds=rounds)
            for ah in (0.1, 1.0)
            for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]]
    out = run_figure(exps)
    for name, logs in out.items():
        print_csv("fig3", name, logs)
    return out


if __name__ == "__main__":
    main()
