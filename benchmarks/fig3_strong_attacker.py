"""Fig. 3 — single STRONG attacker (highest channel gain).

Paper claims (§IV-C): omega_CI > 0 is hard to guarantee -> CI cannot converge
(or converges to failure); BEV still converges; larger alpha_hat converges
faster (under the guarantee).
All four setups run as one compiled sweep (4 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, run_figure
from benchmarks.render_tables import print_sweep_csv

STRONG_SIGMA = 3.0  # attacker channel scale >> honest sigma=1.0


def main(rounds: int = 150):
    exps = [Experiment(name=f"{name}@ah{ah}", policy=pol, n_attackers=1,
                       alpha_hat=ah, attacker_sigma=STRONG_SIGMA,
                       rounds=rounds)
            for ah in (0.1, 1.0)
            for name, pol in [("CI", Policy.CI), ("BEV", Policy.BEV)]]
    result = run_figure(exps)
    print_sweep_csv("fig3", result, eval_every=10)
    return result


if __name__ == "__main__":
    main()
