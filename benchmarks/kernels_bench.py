"""Kernel microbenchmarks: interpret-mode correctness + oracle wall-time.

On this CPU host the Pallas kernels run in interpret mode, so wall-clock
measures the ORACLE (jnp) path; the printed `derived` column is the max
abs error of the kernel vs its oracle (the correctness contract that must
hold before any TPU deployment).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _timeit(fn, *args, iters: int = 5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    key = jax.random.PRNGKey(0)
    rows = []

    u, d = 16, 1 << 20
    ks = jax.random.split(key, 4)
    coeffs = jax.random.normal(ks[0], (u,))
    grads = jax.random.normal(ks[1], (u, d), jnp.float32)
    noise = jax.random.normal(ks[2], (d,))
    bias, eps = jnp.float32(0.1), jnp.float32(0.7)
    t = _timeit(ops.floa_aggregate_ref, coeffs, grads, noise, bias, eps)
    got = ops.floa_aggregate(coeffs, grads, noise, bias, eps)
    want = ops.floa_aggregate_ref(coeffs, grads, noise, bias, eps)
    rows.append(("floa_aggregate_u16_d1M", t,
                 float(jnp.max(jnp.abs(got - want)))))

    t = _timeit(ops.grad_stats_ref, grads)
    got, want = ops.grad_stats(grads), ops.grad_stats_ref(grads)
    err = float(jnp.max(jnp.abs(got - want) / (jnp.abs(want) + 1.0)))  # relative
    rows.append(("grad_stats_u16_d1M", t, err))

    b, h, kv, hd, s = 4, 16, 8, 128, 8192
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    pos = jnp.int32(s - 1)
    t = _timeit(ops.decode_attention_ref, q, k, v, pos)
    err = float(jnp.max(jnp.abs(
        ops.decode_attention(q, k, v, pos) - ops.decode_attention_ref(q, k, v, pos))))
    rows.append(("decode_attention_b4_s8k", t, err))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3e}")


if __name__ == "__main__":
    main()
