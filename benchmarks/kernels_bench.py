"""Kernel microbenchmarks: interpret-mode correctness + oracle wall-time.

On this CPU host the Pallas kernels run in interpret mode, so wall-clock
measures the ORACLE (jnp) path; the printed `derived` column is the max
abs error of the kernel vs its oracle (the correctness contract that must
hold before any TPU deployment).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _timeit(fn, *args, iters: int = 5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main(tiny: bool = False) -> None:
    key = jax.random.PRNGKey(0)
    rows = []

    u, d = 16, (1 << 14 if tiny else 1 << 20)
    dtag = "16k" if tiny else "1M"
    ks = jax.random.split(key, 4)
    coeffs = jax.random.normal(ks[0], (u,))
    grads = jax.random.normal(ks[1], (u, d), jnp.float32)
    noise = jax.random.normal(ks[2], (d,))
    bias, eps = jnp.float32(0.1), jnp.float32(0.7)
    t = _timeit(ops.floa_aggregate_ref, coeffs, grads, noise, bias, eps)
    got = ops.floa_aggregate(coeffs, grads, noise, bias, eps)
    want = ops.floa_aggregate_ref(coeffs, grads, noise, bias, eps)
    rows.append((f"floa_aggregate_u16_d{dtag}", t,
                 float(jnp.max(jnp.abs(got - want)))))

    # batched sweep variant: S scenario lanes over the same [U, D] slab size
    s_n = 2 if tiny else 8
    kb = jax.random.split(jax.random.PRNGKey(1), 5)
    bc = jax.random.normal(kb[0], (s_n, u))
    bg = jax.random.normal(kb[1], (s_n, u, d), jnp.float32)
    bz = jax.random.normal(kb[2], (s_n, d))
    bb = jax.random.normal(kb[3], (s_n,))
    be = jax.random.normal(kb[4], (s_n,))
    t = _timeit(ops.floa_aggregate_batched_ref, bc, bg, bz, bb, be)
    got = ops.floa_aggregate_batched(bc, bg, bz, bb, be)
    want = ops.floa_aggregate_batched_ref(bc, bg, bz, bb, be)
    rows.append((f"floa_aggregate_batched_s{s_n}_u16_d{dtag}", t,
                 float(jnp.max(jnp.abs(got - want)))))

    t = _timeit(ops.grad_stats_ref, grads)
    got, want = ops.grad_stats(grads), ops.grad_stats_ref(grads)
    err = float(jnp.max(jnp.abs(got - want) / (jnp.abs(want) + 1.0)))  # relative
    rows.append((f"grad_stats_u16_d{dtag}", t, err))

    b, h, kv, hd, s = (1, 4, 2, 64, 512) if tiny else (4, 16, 8, 128, 8192)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    pos = jnp.int32(s - 1)
    t = _timeit(ops.decode_attention_ref, q, k, v, pos)
    err = float(jnp.max(jnp.abs(
        ops.decode_attention(q, k, v, pos) - ops.decode_attention_ref(q, k, v, pos))))
    rows.append((f"decode_attention_b{b}_s{s}", t, err))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3e}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small shapes for CI smoke (interpret mode is slow)")
    main(tiny=ap.parse_args().tiny)
