"""Benchmark entrypoint: one function per paper table/figure.

  fig1  no attack (EF/CI/BEV)                §IV-A
  fig2  weakest attacker, alpha_hat sweep    §IV-B
  fig3  strongest attacker                   §IV-C
  fig4  N random attackers                   §IV-D
  defenses  digital screening baselines (beyond paper)
  kernels   Pallas kernel correctness/microbench (name,us_per_call,derived)
  roofline  40-pair dry-run roofline table   (deliverable g)

Set BENCH_ROUNDS to shrink FL rounds (CI smoke: BENCH_ROUNDS=30).
"""
from __future__ import annotations

import os
import sys
import time


def main() -> None:
    rounds = int(os.environ.get("BENCH_ROUNDS", "150"))
    which = sys.argv[1:] or ["fig1", "fig2", "fig3", "fig4", "defenses",
                             "kernels", "roofline"]
    from benchmarks import (defenses_bench, fig1_no_attack, fig2_weak_attacker,
                            fig3_strong_attacker, fig4_multi_attackers,
                            kernels_bench, roofline)

    t0 = time.time()
    if "fig1" in which:
        fig1_no_attack.main(rounds)
    if "fig2" in which:
        fig2_weak_attacker.main(rounds)
    if "fig3" in which:
        fig3_strong_attacker.main(rounds)
    if "fig4" in which:
        fig4_multi_attackers.main(rounds)
    if "defenses" in which:
        defenses_bench.main(min(rounds, 120))
    if "kernels" in which:
        kernels_bench.main()
    if "roofline" in which:
        roofline.main()
    print(f"# benchmarks done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
