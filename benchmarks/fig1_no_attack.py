"""Fig. 1 — no Byzantine attacks: CI ≈ error-free (EF), BEV ~2% behind.

Paper claims (§IV-A): CI matches EF; BEV converges slightly slower/worse
(Remark 6: omega_BEV^2 <= Omega_BEV when N=0).
All three setups run as one compiled sweep (3 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, run_figure
from benchmarks.render_tables import print_sweep_csv


def main(rounds: int = 150):
    exps = [Experiment(name=name, policy=pol, n_attackers=0, alpha_hat=0.1,
                       rounds=rounds)
            for name, pol in [("EF", Policy.EF), ("CI", Policy.CI),
                              ("BEV", Policy.BEV)]]
    result = run_figure(exps)
    print_sweep_csv("fig1", result, eval_every=10)
    return result


if __name__ == "__main__":
    main()
