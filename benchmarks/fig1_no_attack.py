"""Fig. 1 — no Byzantine attacks: CI ≈ error-free (EF), BEV ~2% behind.

Paper claims (§IV-A): CI matches EF; BEV converges slightly slower/worse
(Remark 6: omega_BEV^2 <= Omega_BEV when N=0).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_experiment


def main(rounds: int = 150) -> dict:
    out = {}
    for name, pol in [("EF", Policy.EF), ("CI", Policy.CI), ("BEV", Policy.BEV)]:
        exp = Experiment(name=name, policy=pol, n_attackers=0, alpha_hat=0.1,
                         rounds=rounds)
        logs = run_experiment(exp)
        print_csv("fig1", exp, logs)
        out[name] = logs
    return out


if __name__ == "__main__":
    main()
