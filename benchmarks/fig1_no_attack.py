"""Fig. 1 — no Byzantine attacks: CI ≈ error-free (EF), BEV ~2% behind.

Paper claims (§IV-A): CI matches EF; BEV converges slightly slower/worse
(Remark 6: omega_BEV^2 <= Omega_BEV when N=0).
All three setups run as one compiled sweep (3 lanes x `rounds` scanned).
CSV: fig,experiment,round,loss,accuracy
"""
from benchmarks.common import Experiment, Policy, print_csv, run_figure


def main(rounds: int = 150) -> dict:
    exps = [Experiment(name=name, policy=pol, n_attackers=0, alpha_hat=0.1,
                       rounds=rounds)
            for name, pol in [("EF", Policy.EF), ("CI", Policy.CI),
                              ("BEV", Policy.BEV)]]
    out = run_figure(exps)
    for name, logs in out.items():
        print_csv("fig1", name, logs)
    return out


if __name__ == "__main__":
    main()
