"""End-to-end driver: train a ~0.8M-param StarCoder2-family LM for a few
hundred FLOA rounds on a 4x2 mesh (8 host devices), BEV power control, one
Byzantine worker — the full production stack (mesh, FSDP specs, weighted-loss
OTA aggregation, stale-stat side channel) at CPU-friendly scale.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_floa_lm.py --steps 200
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import get_smoke
from repro.core.power_control import Policy
from repro.data import sample_tokens
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import init_floa_state, init_model, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--byzantine", type=int, default=1)
    args = ap.parse_args()

    mesh = make_debug_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(get_smoke("starcoder2-3b"), model_parallel=2)
    shape = dict(seq_len=args.seq, global_batch=args.batch, kind="train")

    runs = {}
    for name, policy, nb in [("BEV+attack", Policy.BEV, args.byzantine),
                             ("CI+attack", Policy.CI, args.byzantine),
                             ("EF-clean", Policy.EF, 0)]:
        art = make_train_step(cfg, mesh, shape, alpha=0.05, policy=policy,
                              n_byzantine=nb)
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        state = init_floa_state()
        with mesh:
            step_fn = jax.jit(art.fn, in_shardings=art.in_shardings)
            t0, losses = time.time(), []
            for t in range(args.steps):
                toks = jnp.asarray(sample_tokens(
                    args.batch, args.seq + 1, vocab=cfg.vocab_size, seed=t))
                params, state, m = step_fn(params, state, {"tokens": toks},
                                           jnp.uint32(t))
                losses.append(float(m["loss"]))
                if t % 25 == 0:
                    print(f"[{name:10s}] step {t:4d} loss {losses[-1]:7.4f}",
                          flush=True)
        runs[name] = losses
        print(f"[{name:10s}] final loss {losses[-1]:7.4f} "
              f"({time.time() - t0:.1f}s)")

    print("\nsummary (lower = better):")
    for name, losses in runs.items():
        print(f"  {name:10s} start {losses[0]:7.3f} -> final "
              f"{np.mean(losses[-10:]):7.3f}")
    assert np.mean(runs["BEV+attack"][-10:]) < runs["BEV+attack"][0], \
        "BEV under attack failed to make progress"


if __name__ == "__main__":
    main()
