"""End-to-end driver: the real-model LM sweep lane.

Trains a shrunk qwen3-shaped transformer (configs.qwen3_4b.lm_sweep,
D ~ 3.0M flat params — past every kernel-routing threshold) on the Markov
token stream for R FLOA rounds as ONE compiled sweep: three scenario lanes
(clean BEV, sign-flip attack, median screening of the same attack) share the
[S, D] flat state and run in a single `SweepEngine` dispatch.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_floa_lm.py --rounds 20

  # ("model",)-sharded big-D state over 4 fake devices:
  python examples/train_floa_lm.py --model-shards 4

  # Preemption-safe: checkpoint at chunk boundaries, rerun with --resume.
  python examples/train_floa_lm.py --checkpoint-dir /tmp/lm_ckpt --resume

--smoke shrinks the model to D ~ 70k for a seconds-scale CPU sanity pass.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import time

import jax
import numpy as np

jax.config.update("jax_threefry_partitionable", True)

from repro.configs.registry import flat_param_dim, get_lm_sweep
from repro.core import (
    AttackConfig,
    AttackType,
    ChannelConfig,
    DefenseSpec,
    FLOAConfig,
    Policy,
    PowerConfig,
    first_n_mask,
)
from repro.data import stack_token_rounds
from repro.fl import ExecutionPlan, ScenarioCase, SweepEngine, SweepSpec
from repro.launch.mesh import make_sweep_mesh
from repro.models.transformer import init_lm, lm_loss


def lm_lanes(u: int, dim: int, n_atk: int, lr: float):
    """The three-lane showdown: no-attack BEV FLOA, the Thm-1 sign-flip
    attack on the same channel, and median screening of that attack."""
    def floa(policy, attack, n, noise=0.05):
        return FLOAConfig(
            channel=ChannelConfig(num_workers=u, sigma=1.0,
                                  noise_std=0.0 if policy == Policy.EF
                                  else noise),
            power=PowerConfig(num_workers=u, dim=dim, p_max=1.0,
                              policy=policy),
            attack=AttackConfig(attack=attack if n else AttackType.NONE,
                                byzantine_mask=first_n_mask(u, n)))

    return [
        ScenarioCase("bev-clean", floa(Policy.BEV, AttackType.NONE, 0),
                     lr, seed=11),
        ScenarioCase("bev-signflip",
                     floa(Policy.BEV, AttackType.STRONGEST, n_atk),
                     lr, seed=12),
        ScenarioCase("median-signflip",
                     floa(Policy.EF, AttackType.STRONGEST, n_atk, noise=0.0),
                     lr, seed=13, defense=DefenseSpec(name="median")),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--byzantine", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--model-shards", type=int, default=1,
                    help="shard the flat [S, D] state's D axis over this "
                         "many devices (adds a ('model',) mesh axis)")
    ap.add_argument("--chunk-rounds", type=int, default=None,
                    help="scan-of-chunks execution (required with "
                         "--checkpoint-dir; defaults to rounds//4 then)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="preemption-safe resume checkpoints at chunk "
                         "boundaries")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint and run only the "
                         "remaining chunks (fresh run if none exists)")
    ap.add_argument("--smoke", action="store_true",
                    help="D ~ 70k seconds-scale variant of the same lane")
    args = ap.parse_args()

    cfg = get_lm_sweep()
    if args.smoke:
        cfg = dataclasses.replace(
            cfg, n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
            head_dim=16, d_ff=128, vocab_size=256)
    dim = flat_param_dim(cfg)
    print(f"model {cfg.name}: {cfg.n_layers}L d_model={cfg.d_model} "
          f"vocab={cfg.vocab_size} -> flat D = {dim:,}")

    u = args.workers
    spec = SweepSpec.build(lm_lanes(u, dim, args.byzantine, args.lr))
    # One Markov token batch per round, [R, U*B, S+1]; per_worker_grads
    # splits the row axis into U workers of B sequences each.
    batches = {"tokens": stack_token_rounds(
        args.rounds, u * args.batch, args.seq + 1, cfg.vocab_size, seed=0)}
    params0, _ = init_lm(jax.random.PRNGKey(0), cfg)

    chunk = args.chunk_rounds
    if args.checkpoint_dir is not None and chunk is None:
        chunk = max(1, args.rounds // 4)
    mesh = (make_sweep_mesh(model_shards=args.model_shards)
            if args.model_shards > 1 else None)
    plan = ExecutionPlan(mesh=mesh, chunk_rounds=chunk,
                         checkpoint_dir=args.checkpoint_dir)
    engine = SweepEngine(lambda p, b: lm_loss(p, b, cfg), spec, plan=plan)

    t0 = time.time()
    res = engine.run(params0, batches, resume=args.resume)
    dt = time.time() - t0

    print(f"\n{args.rounds} rounds x {len(spec.cases)} lanes in one "
          f"compiled sweep ({dt:.1f}s):")
    tail = max(1, args.rounds // 5)
    for i, name in enumerate(res.names):
        ls = res.loss[i]
        print(f"  {name:16s} loss {ls[0]:7.4f} -> {np.mean(ls[-tail:]):7.4f}")
    clean = res.loss[list(res.names).index("bev-clean")]
    assert np.mean(clean[-tail:]) < clean[0], \
        "clean BEV lane failed to reduce LM loss"


if __name__ == "__main__":
    main()
