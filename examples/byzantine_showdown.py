"""Byzantine showdown (beyond paper): FLOA-BEV vs FLOA-CI vs digital
screening defenses (median / trimmed-mean / Krum / geometric median) under
increasing attacker counts.  One table, every defense philosophy.

Digital defenses see per-worker gradients (U x uplink cost, no privacy);
FLOA sees only the analog superposition (1 x uplink, gradient-private) —
the paper's whole trade-off, quantified.

  PYTHONPATH=src python examples/byzantine_showdown.py
"""
import jax
import jax.numpy as jnp

jax.config.update("jax_threefry_partitionable", True)

from repro.configs.registry import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import FLTrainer
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

ROUNDS = 100


def setup():
    mc = PAPER_MLP.full()
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    return (mc, worker_split(x, y, mc.num_workers),
            jnp.asarray(xt), jnp.asarray(yt))


def run(mc, shards, xt, yt, mode, n_atk, policy=Policy.BEV, defense="mean",
        **dkw):
    u, d = mc.num_workers, mc.dim
    tp = theory.TheoryParams(num_workers=u, num_attackers=n_atk, dim=d)
    if mode == "floa":
        pol = policy.value
        alpha = theory.alpha_from_alpha_hat(tp, pol, 0.1)
        noise = noise_std_for_snr(mc.p_max, d, mc.snr_db)
    else:
        alpha, noise, policy = 0.1, 0.0, Policy.EF
    floa = FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=noise),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max, policy=policy),
        attack=AttackConfig(
            attack=AttackType.STRONGEST if n_atk else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n_atk)),
    )
    tr = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha, mode=mode,
                   defense=defense, defense_kwargs=dkw,
                   eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt, yt)})
    sampler = FederatedSampler(shards, mc.batch_per_worker, seed=1)
    _, logs = tr.run(init_mlp(jax.random.PRNGKey(0)), sampler, ROUNDS,
                     jax.random.PRNGKey(5), eval_every=ROUNDS - 1)
    return logs[-1].accuracy


def main() -> None:
    mc, shards, xt, yt = setup()
    contenders = [
        ("FLOA-BEV (analog, private)", dict(mode="floa", policy=Policy.BEV)),
        ("FLOA-CI  (analog, private)", dict(mode="floa", policy=Policy.CI)),
        ("digital mean (no defense)", dict(mode="digital", defense="mean")),
        ("digital median", dict(mode="digital", defense="median")),
        ("digital trimmed-mean(3)", dict(mode="digital",
                                         defense="trimmed_mean", trim=3)),
        ("digital Krum(f=3)", dict(mode="digital", defense="krum",
                                   num_byzantine=3)),
        ("digital geometric-median", dict(mode="digital",
                                          defense="geometric_median")),
    ]
    ns = [0, 1, 3, 4]
    print(f"{'defense':30s} " + " ".join(f"N={n:<4d}" for n in ns))
    for name, kw in contenders:
        accs = []
        for n in ns:
            kw2 = dict(kw)
            extra = {k: v for k, v in kw2.items()
                     if k not in ("mode", "policy", "defense")}
            accs.append(run(mc, shards, xt, yt, kw2.get("mode"), n,
                            policy=kw2.get("policy", Policy.BEV),
                            defense=kw2.get("defense", "mean"), **extra))
        print(f"{name:30s} " + " ".join(f"{a:.3f}" for a in accs))


if __name__ == "__main__":
    main()
