"""Byzantine showdown (beyond paper): FLOA-BEV vs FLOA-CI vs digital
screening defenses (median / trimmed-mean / Krum / geometric median) under
increasing attacker counts.  One table, every defense philosophy.

Digital defenses see per-worker gradients (U x uplink cost, no privacy);
FLOA sees only the analog superposition (1 x uplink, gradient-private) —
the paper's whole trade-off, quantified.

Execution: every FLOA cell (policy x attacker count) is one lane of a single
compiled sweep (fl.sweep) — one compile, one dispatch for the whole analog
half of the table.  Digital cells go through FLTrainer.run_scan (defense
screening needs per-worker gradients and per-defense code paths, so each
defense is its own scanned program, still with zero per-round dispatch).

  PYTHONPATH=src python examples/byzantine_showdown.py
"""
import jax
import jax.numpy as jnp

jax.config.update("jax_threefry_partitionable", True)

from repro.configs.registry import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import FLTrainer, ScenarioCase, SweepSpec, run_sweep
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss

ROUNDS = 100
NS = [0, 1, 3, 4]


def setup():
    mc = PAPER_MLP.full()
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    return (mc, worker_split(x, y, mc.num_workers),
            jnp.asarray(xt), jnp.asarray(yt))


def floa_config(mc, n_atk: int, policy: Policy, noise: float) -> FLOAConfig:
    u, d = mc.num_workers, mc.dim
    return FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=noise),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max, policy=policy),
        attack=AttackConfig(
            attack=AttackType.STRONGEST if n_atk else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n_atk)),
    )


def run_floa_grid(mc, batches, params, eval_fn):
    """All FLOA (policy x N) cells as one compiled sweep; returns
    {(policy, n): final accuracy}."""
    u, d = mc.num_workers, mc.dim
    noise = noise_std_for_snr(mc.p_max, d, mc.snr_db)
    cases = []
    for policy in (Policy.BEV, Policy.CI):
        for n in NS:
            tp = theory.TheoryParams(num_workers=u, num_attackers=n, dim=d)
            alpha = theory.alpha_from_alpha_hat(tp, policy.value, 0.1)
            cases.append(ScenarioCase(f"{policy.value}@N{n}",
                                      floa_config(mc, n, policy, noise),
                                      alpha, seed=5))
    result = run_sweep(mlp_loss, params, batches, SweepSpec.build(cases),
                       eval_fn=eval_fn, eval_every=ROUNDS)  # final acc only
    return {name: float(result.metrics["accuracy"][i, -1])
            for i, name in enumerate(result.names)}


def run_digital(mc, batches, params, eval_fn, n_atk: int, defense: str,
                **dkw) -> float:
    """One digital cell: gathered per-worker gradients + screening defense,
    rounds scanned (run_scan) so there is no per-round Python dispatch."""
    floa = floa_config(mc, n_atk, Policy.EF, 0.0)
    tr = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=0.1, mode="digital",
                   defense=defense, defense_kwargs=dkw,
                   eval_fn=eval_fn)
    _, logs = tr.run_scan(params, batches, jax.random.PRNGKey(5),
                          eval_every=ROUNDS - 1)
    return logs[-1].accuracy


def main() -> None:
    mc, shards, xt, yt = setup()
    eval_fn = lambda p: {"accuracy": mlp_accuracy(p, xt, yt)}
    params = init_mlp(jax.random.PRNGKey(0))
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(ROUNDS)

    floa_accs = run_floa_grid(mc, batches, params, eval_fn)
    digital = [
        ("digital mean (no defense)", dict(defense="mean")),
        ("digital median", dict(defense="median")),
        ("digital trimmed-mean(3)", dict(defense="trimmed_mean", trim=3)),
        ("digital Krum(f=3)", dict(defense="krum", num_byzantine=3)),
        ("digital geometric-median", dict(defense="geometric_median")),
    ]

    print(f"{'defense':30s} " + " ".join(f"N={n:<4d}" for n in NS))
    for policy, label in [(Policy.BEV, "FLOA-BEV (analog, private)"),
                          (Policy.CI, "FLOA-CI  (analog, private)")]:
        accs = [floa_accs[f"{policy.value}@N{n}"] for n in NS]
        print(f"{label:30s} " + " ".join(f"{a:.3f}" for a in accs))
    for name, kw in digital:
        extra = {k: v for k, v in kw.items() if k != "defense"}
        accs = [run_digital(mc, batches, params, eval_fn, n,
                            kw["defense"], **extra) for n in NS]
        print(f"{name:30s} " + " ".join(f"{a:.3f}" for a in accs))


if __name__ == "__main__":
    main()
