"""Byzantine showdown (beyond paper): FLOA-BEV vs FLOA-CI vs digital
screening defenses (median / trimmed-mean / Krum / multi-Krum / geometric
median) under increasing attacker counts.  One table, every defense
philosophy.

Digital defenses see per-worker gradients (U x uplink cost via an
all-gather, no privacy); FLOA sees only the analog superposition (1 x
uplink all-reduce, gradient-private) — the paper's whole trade-off,
quantified.

Execution: EVERY cell — analog (policy x attacker count) and digital
(defense x attacker count) — is one lane of a single compiled sweep: the
defense-code lane axis (core.scenario.DEFENSE_CODES) selects per lane
between the OTA `floa_step` combine and a screening defense on the same
[S, U, D] gradient slab, so the whole table is one XLA program, one
compile, one dispatch.  Zero per-defense programs.

  PYTHONPATH=src python examples/byzantine_showdown.py
  REPRO_SMOKE=1 PYTHONPATH=src python examples/byzantine_showdown.py  # tiny CI
"""
import os

import jax

jax.config.update("jax_threefry_partitionable", True)

import jax.numpy as jnp

from repro.configs import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, DefenseSpec, FLOAConfig, Policy,
    PowerConfig, first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import ScenarioCase, SweepSpec, run_sweep
from repro.models import init_mlp, mlp_accuracy, mlp_loss

# Smoke mode (CI): the same policy x defense x attacker-count grid — every
# defense family, mixed with the analog lanes, through the grouped dispatch —
# on the tiny config with a handful of rounds.
SMOKE = bool(os.environ.get("REPRO_SMOKE"))

ROUNDS = 6 if SMOKE else 100
NS = [0, 1, 3, 4]

DIGITAL = [
    ("digital mean (no defense)", DefenseSpec(name="mean")),
    ("digital median", DefenseSpec(name="median")),
    ("digital trimmed-mean(3)", DefenseSpec(name="trimmed_mean", trim=3)),
    ("digital Krum(f=3)", DefenseSpec(name="krum", num_byzantine=3)),
    ("digital multi-Krum(f=3,m=3)",
     DefenseSpec(name="multi_krum", num_byzantine=3, multi=3)),
    ("digital geometric-median", DefenseSpec(name="geometric_median")),
]


def setup():
    mc = PAPER_MLP.smoke() if SMOKE else PAPER_MLP.full()
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    return (mc, worker_split(x, y, mc.num_workers),
            jnp.asarray(xt), jnp.asarray(yt))


def floa_config(mc, n_atk: int, policy: Policy, noise: float) -> FLOAConfig:
    u, d = mc.num_workers, mc.dim
    return FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=noise),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max, policy=policy),
        attack=AttackConfig(
            attack=AttackType.STRONGEST if n_atk else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n_atk)),
    )


def build_cases(mc):
    """The whole showdown grid — analog policies AND digital defenses — as
    lanes of one sweep.  Digital lanes ride an EF/noiseless channel config
    (their defense code ignores the channel; attackers are modelled as
    sign-flipped reported gradients, the digital-FL threat model)."""
    u, d = mc.num_workers, mc.dim
    noise = noise_std_for_snr(mc.p_max, d, mc.snr_db)
    cases = []
    for policy in (Policy.BEV, Policy.CI):
        for n in NS:
            tp = theory.TheoryParams(num_workers=u, num_attackers=n, dim=d)
            alpha = theory.alpha_from_alpha_hat(tp, policy.value, 0.1)
            cases.append(ScenarioCase(f"{policy.value}@N{n}",
                                      floa_config(mc, n, policy, noise),
                                      alpha, seed=5))
    for label, defense in DIGITAL:
        for n in NS:
            cases.append(ScenarioCase(f"{label}@N{n}",
                                      floa_config(mc, n, Policy.EF, 0.0),
                                      0.1, seed=5, defense=defense))
    return cases


def main() -> None:
    mc, shards, xt, yt = setup()
    eval_fn = lambda p: {"accuracy": mlp_accuracy(p, xt, yt)}
    params = init_mlp(jax.random.PRNGKey(0))
    batches = FederatedSampler(shards, mc.batch_per_worker,
                               seed=1).stack_rounds(ROUNDS)

    cases = build_cases(mc)
    result = run_sweep(mlp_loss, params, batches, SweepSpec.build(cases),
                       eval_fn=eval_fn, eval_every=ROUNDS)  # final acc only
    acc = {name: float(result.metrics["accuracy"][i, -1])
           for i, name in enumerate(result.names)}

    print(f"{'defense':30s} " + " ".join(f"N={n:<4d}" for n in NS))
    rows = [("FLOA-BEV (analog, private)", f"{Policy.BEV.value}@N"),
            ("FLOA-CI  (analog, private)", f"{Policy.CI.value}@N")]
    rows += [(label, f"{label}@N") for label, _ in DIGITAL]
    for label, prefix in rows:
        accs = [acc[f"{prefix}{n}"] for n in NS]
        print(f"{label:30s} " + " ".join(f"{a:.3f}" for a in accs))


if __name__ == "__main__":
    main()
