"""Byzantine showdown (beyond paper): FLOA-BEV vs FLOA-CI vs digital
screening defenses (median / trimmed-mean / Krum / multi-Krum / geometric
median) under increasing attacker counts — plus the adaptive-adversary
axes: colluding and omniscient cohorts, Gauss-Markov fading, and K-of-U
client sampling.  One table, every defense philosophy.

Digital defenses see per-worker gradients (U x uplink cost via an
all-gather, no privacy); FLOA sees only the analog superposition (1 x
uplink all-reduce, gradient-private) — the paper's whole trade-off,
quantified.

Execution: EVERY cell — analog (policy x attacker count), digital
(defense x attacker count), and every adaptive-adversary variant — is one
lane of a single compiled sweep: the defense-code lane axis
(core.scenario.DEFENSE_CODES) selects per lane between the OTA
`floa_step` combine and a screening defense, attack codes 4/5 inject the
colluding/omniscient directional payloads, `markov_rho` lanes thread the
Gauss-Markov fading carry, and `participants=K` lanes mask the
non-participants out of stats, combine, and screening — all on the same
[S, U, D] gradient slab, so the whole table is one XLA program, one
compile, one dispatch.  Zero per-defense programs.

  PYTHONPATH=src python examples/byzantine_showdown.py
  PYTHONPATH=src python examples/byzantine_showdown.py --dirichlet 0.3
  REPRO_SMOKE=1 PYTHONPATH=src python examples/byzantine_showdown.py  # tiny CI

Preemption-safe mode (docs/checkpointing.md): --checkpoint-dir snapshots the
sweep at chunk boundaries and --resume continues a killed run bit-identically:

  PYTHONPATH=src python examples/byzantine_showdown.py \
      --checkpoint-dir /tmp/showdown_ckpt --resume
"""
import argparse
import os

import jax

jax.config.update("jax_threefry_partitionable", True)

import jax.numpy as jnp

from repro import ExecutionPlan, setup_compilation_cache
from repro.configs import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, DefenseSpec, FLOAConfig, Policy,
    PowerConfig, first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import ScenarioCase, SweepSpec, run_sweep
from repro.models import init_mlp, mlp_accuracy, mlp_loss

# Smoke mode (CI): the same policy x defense x attacker-count grid — every
# defense family, every adaptive-adversary axis, mixed with the analog lanes
# through the grouped dispatch — on the tiny config with a handful of rounds.
SMOKE = bool(os.environ.get("REPRO_SMOKE"))

ROUNDS = 6 if SMOKE else 100
NS = [0, 1, 3, 4]
NS_ATK = [n for n in NS if n > 0]
MARKOV_RHO = 0.9
# K-of-U participation: K=7 of U=10 satisfies every digital lane's
# per-round hyper-parameter bound (2*trim < K, krum f <= K-3, m <= K).
PART_K = 7

DIGITAL = [
    ("digital mean (no defense)", DefenseSpec(name="mean")),
    ("digital median", DefenseSpec(name="median")),
    ("digital trimmed-mean(3)", DefenseSpec(name="trimmed_mean", trim=3)),
    ("digital Krum(f=3)", DefenseSpec(name="krum", num_byzantine=3)),
    ("digital multi-Krum(f=3,m=3)",
     DefenseSpec(name="multi_krum", num_byzantine=3, multi=3)),
    ("digital geometric-median", DefenseSpec(name="geometric_median")),
]
DIGITAL_PART = [
    ("digital median", DefenseSpec(name="median")),
    ("digital trimmed-mean(3)", DefenseSpec(name="trimmed_mean", trim=3)),
]
DIRECTIONAL = [("colluding", AttackType.COLLUDING),
               ("omniscient", AttackType.OMNISCIENT)]


def setup(dirichlet_alpha):
    mc = PAPER_MLP.smoke() if SMOKE else PAPER_MLP.full()
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    if dirichlet_alpha is None:
        sampler = FederatedSampler(worker_split(x, y, mc.num_workers),
                                   mc.batch_per_worker, seed=1)
    else:
        sampler = FederatedSampler.dirichlet(
            x, y, mc.num_workers, dirichlet_alpha, mc.batch_per_worker, seed=1)
    return mc, sampler, jnp.asarray(xt), jnp.asarray(yt)


def floa_config(mc, n_atk: int, policy: Policy, noise: float,
                attack: AttackType = AttackType.STRONGEST,
                markov_rho: float = 0.0) -> FLOAConfig:
    u, d = mc.num_workers, mc.dim
    return FLOAConfig(
        channel=ChannelConfig(num_workers=u, sigma=1.0, noise_std=noise,
                              markov_rho=markov_rho),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max, policy=policy),
        attack=AttackConfig(
            attack=attack if n_atk else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n_atk)),
    )


def _theory_alpha(mc, n: int, policy: Policy) -> float:
    tp = theory.TheoryParams(num_workers=mc.num_workers, num_attackers=n,
                             dim=mc.dim)
    return theory.alpha_from_alpha_hat(tp, policy.value, 0.1)


def build_cases(mc):
    """The whole showdown grid — analog policies, digital defenses, and the
    adaptive-adversary variants — as lanes of one sweep.  Digital lanes ride
    an EF/noiseless channel config (their defense code ignores the channel;
    attackers are modelled as sign-flipped reported gradients, the
    digital-FL threat model)."""
    noise = noise_std_for_snr(mc.p_max, mc.dim, mc.snr_db)
    cases = []
    for policy in (Policy.BEV, Policy.CI):
        pv = policy.value
        for n in NS:
            alpha = _theory_alpha(mc, n, policy)
            cases.append(ScenarioCase(
                f"{pv}@N{n}", floa_config(mc, n, policy, noise),
                alpha, seed=5))
            # Gauss-Markov fading: same grid, correlated channel rounds.
            cases.append(ScenarioCase(
                f"{pv}/markov@N{n}",
                floa_config(mc, n, policy, noise, markov_rho=MARKOV_RHO),
                alpha, seed=5))
            # K-of-U client sampling: only PART_K workers transmit per round.
            cases.append(ScenarioCase(
                f"{pv}/K{PART_K}@N{n}", floa_config(mc, n, policy, noise),
                alpha, seed=5, participants=PART_K))
        # Colluding / omniscient cohorts (need at least one attacker).
        for tag, atk in DIRECTIONAL:
            for n in NS_ATK:
                cases.append(ScenarioCase(
                    f"{pv}/{tag}@N{n}",
                    floa_config(mc, n, policy, noise, attack=atk),
                    _theory_alpha(mc, n, policy), seed=5))
    for label, defense in DIGITAL:
        for n in NS:
            cases.append(ScenarioCase(
                f"{label}@N{n}", floa_config(mc, n, Policy.EF, 0.0),
                0.1, seed=5, defense=defense))
    # Screening under partial participation: the kernels reduce over the
    # round's K participants only.
    for label, defense in DIGITAL_PART:
        for n in NS:
            cases.append(ScenarioCase(
                f"{label}/K{PART_K}@N{n}", floa_config(mc, n, Policy.EF, 0.0),
                0.1, seed=5, defense=defense, participants=PART_K))
    return cases


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--dirichlet", type=float, default=None, metavar="ALPHA",
                    help="partition training data by a Dirichlet(ALPHA) "
                         "label-skew split instead of the IID round-robin "
                         "(smaller = more skew)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="snapshot the sweep's resume carry at chunk "
                         "boundaries under DIR (preemption-safe; implies "
                         "chunked execution)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint in "
                         "--checkpoint-dir (bit-identical to the "
                         "uninterrupted run; fresh start if none exists)")
    args = ap.parse_args()
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    setup_compilation_cache()  # no-op unless $REPRO_COMPILATION_CACHE is set

    mc, sampler, xt, yt = setup(args.dirichlet)
    eval_fn = lambda p: {"accuracy": mlp_accuracy(p, xt, yt)}
    params = init_mlp(jax.random.PRNGKey(0))
    batches = sampler.stack_rounds(ROUNDS)

    plan = ExecutionPlan()
    if args.checkpoint_dir:
        plan = ExecutionPlan(chunk_rounds=max(1, ROUNDS // 4),
                             checkpoint_dir=args.checkpoint_dir)
    cases = build_cases(mc)
    result = run_sweep(mlp_loss, params, batches, SweepSpec.build(cases),
                       eval_fn=eval_fn, eval_every=ROUNDS,  # final acc only
                       plan=plan, resume=args.resume)
    acc = {name: float(result.metrics["accuracy"][i, -1])
           for i, name in enumerate(result.names)}

    part = "IID" if args.dirichlet is None else f"Dirichlet({args.dirichlet})"
    print(f"# {len(cases)} lanes, one compiled sweep; data: {part}")
    print(f"{'defense':30s} " + " ".join(f"N={n:<4d}" for n in NS))
    rows = [("FLOA-BEV (analog, private)", f"{Policy.BEV.value}@N"),
            ("FLOA-CI  (analog, private)", f"{Policy.CI.value}@N")]
    for policy in (Policy.BEV, Policy.CI):
        pv = policy.value
        rows += [(f"FLOA-{pv.upper()} markov({MARKOV_RHO})",
                  f"{pv}/markov@N"),
                 (f"FLOA-{pv.upper()} K={PART_K} of U",
                  f"{pv}/K{PART_K}@N")]
        rows += [(f"FLOA-{pv.upper()} {tag}", f"{pv}/{tag}@N")
                 for tag, _ in DIRECTIONAL]
    rows += [(label, f"{label}@N") for label, _ in DIGITAL]
    rows += [(f"{label} K={PART_K}", f"{label}/K{PART_K}@N")
             for label, _ in DIGITAL_PART]
    for label, prefix in rows:
        cells = [acc.get(f"{prefix}{n}") for n in NS]
        print(f"{label:30s} " + " ".join(
            "--   " if a is None else f"{a:.3f}" for a in cells))


if __name__ == "__main__":
    main()
