"""Quickstart: the paper in 60 seconds — as ONE compiled sweep.

Trains the paper's MLP (784-64-10, D=50890) over a simulated wireless MAC
with U=10 workers under five setups at once — error-free, CI, and BEV benign,
plus CI and BEV with 3 Byzantine workers mounting the strongest attack
(Thm 1).  All five are lanes of a single scan x vmap program (fl.sweep), so
the whole demo is one compile + one dispatch.  Reproduces the paper's
headline: CI ≈ EF when benign but collapses under attack; BEV pays ~2%
benign accuracy for robustness.

  PYTHONPATH=src python examples/quickstart.py
  REPRO_SMOKE=1 PYTHONPATH=src python examples/quickstart.py   # tiny CI mode
"""
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_threefry_partitionable", True)

from repro import setup_compilation_cache
from repro.configs import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import ScenarioCase, SweepSpec, run_sweep
from repro.models import init_mlp, mlp_accuracy, mlp_loss

SMOKE = bool(os.environ.get("REPRO_SMOKE"))

# Persistent XLA compilation cache (no-op unless $REPRO_COMPILATION_CACHE is
# set): a restarted demo skips the sweep recompile.  See docs/checkpointing.md.
setup_compilation_cache()


def case(name: str, policy: Policy, n_attackers: int, mc) -> ScenarioCase:
    u, d = mc.num_workers, mc.dim
    tp = theory.TheoryParams(num_workers=u, num_attackers=n_attackers, dim=d)
    pol = "ef" if policy == Policy.EF else policy.value
    alpha = theory.alpha_from_alpha_hat(tp, pol, alpha_hat=0.1)
    floa = FLOAConfig(
        channel=ChannelConfig(
            num_workers=u, sigma=mc.sigma,
            noise_std=0.0 if policy == Policy.EF
            else noise_std_for_snr(mc.p_max, d, mc.snr_db)),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max, policy=policy),
        attack=AttackConfig(
            attack=AttackType.STRONGEST if n_attackers else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n_attackers)),
    )
    return ScenarioCase(name, floa, alpha, seed=1)


def main(rounds: int = 120) -> dict:
    mc = PAPER_MLP.smoke() if SMOKE else PAPER_MLP.full()
    if SMOKE:
        rounds = min(rounds, 10)
    spec = SweepSpec.build([
        case("EF benign", Policy.EF, 0, mc),
        case("CI benign", Policy.CI, 0, mc),
        case("BEV benign", Policy.BEV, 0, mc),
        case("CI 3-attackers", Policy.CI, 3, mc),
        case("BEV 3-attackers", Policy.BEV, 3, mc),
    ])
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)
    batches = FederatedSampler(worker_split(x, y, mc.num_workers),
                               mc.batch_per_worker).stack_rounds(rounds)
    result = run_sweep(
        mlp_loss, init_mlp(jax.random.PRNGKey(0)), batches, spec,
        eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt, yt)},
        eval_every=rounds)  # only the final accuracy matters here

    accs = {name: float(result.metrics["accuracy"][i, -1])
            for i, name in enumerate(result.names)}
    print("== benign (no attackers) ==")
    for name in ("EF benign", "CI benign", "BEV benign"):
        print(f"  {name:16s} test accuracy: {accs[name]:.3f}")
    print("== 3 Byzantine workers, strongest attack (Thm 1) ==")
    for name in ("CI 3-attackers", "BEV 3-attackers"):
        print(f"  {name:16s} test accuracy: {accs[name]:.3f}")
    print("-> BEV trades a sliver of benign accuracy for Byzantine robustness.")
    return accs


if __name__ == "__main__":
    main()
