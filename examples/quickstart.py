"""Quickstart: the paper in 60 seconds.

Trains the paper's MLP (784-64-10, D=50890) over a simulated wireless MAC
with U=10 workers under three setups — error-free, CI, and BEV — then repeats
with 3 Byzantine workers mounting the strongest attack (Thm 1).  Reproduces
the paper's headline: CI ≈ EF when benign but collapses under attack; BEV
pays ~2% benign accuracy for robustness.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

jax.config.update("jax_threefry_partitionable", True)

from repro.configs.registry import PAPER_MLP
from repro.core import (
    AttackConfig, AttackType, ChannelConfig, FLOAConfig, Policy, PowerConfig,
    first_n_mask, noise_std_for_snr,
)
from repro.core import theory
from repro.data import FederatedSampler, make_dataset, worker_split
from repro.fl import FLTrainer
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss


def run(policy: Policy, n_attackers: int, rounds: int = 120) -> float:
    mc = PAPER_MLP.full()
    u, d = mc.num_workers, mc.dim
    tp = theory.TheoryParams(num_workers=u, num_attackers=n_attackers, dim=d)
    pol = "ef" if policy == Policy.EF else policy.value
    alpha = theory.alpha_from_alpha_hat(tp, pol, alpha_hat=0.1)
    floa = FLOAConfig(
        channel=ChannelConfig(
            num_workers=u, sigma=mc.sigma,
            noise_std=0.0 if policy == Policy.EF
            else noise_std_for_snr(mc.p_max, d, mc.snr_db)),
        power=PowerConfig(num_workers=u, dim=d, p_max=mc.p_max, policy=policy),
        attack=AttackConfig(
            attack=AttackType.STRONGEST if n_attackers else AttackType.NONE,
            byzantine_mask=first_n_mask(u, n_attackers)),
    )
    x, y = make_dataset(mc.train_samples, seed=0)
    xt, yt = make_dataset(mc.test_samples, seed=99)
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)
    trainer = FLTrainer(loss_fn=mlp_loss, floa=floa, alpha=alpha,
                        eval_fn=lambda p: {"accuracy": mlp_accuracy(p, xt, yt)})
    sampler = FederatedSampler(worker_split(x, y, u), mc.batch_per_worker)
    _, logs = trainer.run(init_mlp(jax.random.PRNGKey(0)), sampler, rounds,
                          jax.random.PRNGKey(1), eval_every=rounds - 1)
    return logs[-1].accuracy


if __name__ == "__main__":
    print("== benign (no attackers) ==")
    for pol in (Policy.EF, Policy.CI, Policy.BEV):
        print(f"  {pol.value.upper():4s} test accuracy: {run(pol, 0):.3f}")
    print("== 3 Byzantine workers, strongest attack (Thm 1) ==")
    for pol in (Policy.CI, Policy.BEV):
        print(f"  {pol.value.upper():4s} test accuracy: {run(pol, 3):.3f}")
    print("-> BEV trades a sliver of benign accuracy for Byzantine robustness.")
