"""Batched serving example: prefill-by-decode + generation with a sharded KV
cache on a 4x2 mesh, using the smoke Qwen3 config (qk-norm GQA).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/serve_batch.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import dataclasses
import time

import jax
import jax.numpy as jnp

jax.config.update("jax_threefry_partitionable", True)

from repro.configs import get_smoke
from repro.data import sample_tokens
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import init_model, make_decode_step
from repro.models import transformer as T


def main() -> None:
    batch, prompt_len, gen_len = 8, 24, 24
    mesh = make_debug_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(get_smoke("qwen3-4b"), model_parallel=2)
    max_len = prompt_len + gen_len
    art = make_decode_step(cfg, mesh,
                           dict(seq_len=max_len, global_batch=batch,
                                kind="decode"), "decode_32k")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    prompts = jnp.asarray(sample_tokens(batch, prompt_len,
                                        vocab=cfg.vocab_size, seed=3))
    caches = T.init_caches(cfg, batch, max_len)

    with mesh:
        step = jax.jit(art.fn, in_shardings=art.in_shardings)
        t0 = time.time()
        for i in range(prompt_len):
            logits, caches = step(params, caches, prompts[:, i:i + 1],
                                  jnp.int32(i))
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
        outs = []
        for i in range(prompt_len, max_len):
            outs.append(tok)
            logits, caches = step(params, caches, tok, jnp.int32(i))
            tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
        dt = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    print(f"served batch={batch}: {prompt_len} prompt + {gen_len} generated "
          f"tokens/seq in {dt:.1f}s ({batch * gen_len / dt:.1f} tok/s)")
    for b in range(2):
        print(f"  seq{b}: prompt {prompts[b, :8].tolist()} -> "
              f"gen {gen[b, :8].tolist()}")


if __name__ == "__main__":
    main()
